"""Multi-client serving engine (paper §3.7 / §4.4-style deployment).

Drives real model execution for a bank of inference clients that share one
frozen base. Each client owns its adapter + KV cache (client-side state);
decode steps are *opportunistically batched*: at every engine tick, the
clients that have work ready are batched into one multi-client decode call.
Clients can run at different rates (a client whose request finished or whose
per-step budget is exhausted simply sits out a tick) — the JAX analogue of
"requests batched at the first layer need not batch at later layers".

For latency realism the engine also reports a scheduler-simulated timeline
(core.scheduler) calibrated with measured per-op costs; the *outputs* are
produced by the real batched execution and are invariant to the policy, a
property asserted in tests (paper: "the output with Symbiosis is exactly
identical to that of the baseline").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, ModelConfig, ServeConfig
from repro.core import symbiosis
from repro.core.scheduler import ClientSpec, simulate


@dataclasses.dataclass
class Request:
    client_id: int
    prompt: np.ndarray                      # [B, S] int32
    max_new_tokens: int = 16
    latency_sensitive: bool = True
    # filled by the engine:
    generated: Optional[np.ndarray] = None  # [B, max_new_tokens]
    submit_t: float = 0.0
    finish_t: float = 0.0


class ServingEngine:
    """One base model serving a bank of adapter clients."""

    def __init__(self, cfg: ModelConfig, acfg: AdapterConfig, scfg: ServeConfig,
                 base_params, client_bank, *, max_batch_per_client: int = 4):
        self.cfg, self.acfg, self.scfg = cfg, acfg, scfg
        self.base = base_params
        self.bank = client_bank
        self.n_clients = jax.tree.leaves(client_bank)[0].shape[0]
        self.max_b = max_batch_per_client
        self.caches = symbiosis.init_client_caches(
            cfg, self.n_clients, max_batch_per_client, scfg.max_seq)
        self._prefill = jax.jit(symbiosis.make_multi_client_prefill(cfg, acfg, scfg))
        self._decode = jax.jit(symbiosis.make_multi_client_decode_step(cfg, acfg, scfg))
        self._queue: List[Request] = []
        self.stats = {"ticks": 0, "decode_tokens": 0, "batched_clients": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert 0 <= req.client_id < self.n_clients
        assert req.prompt.shape[0] <= self.max_b
        req.submit_t = time.perf_counter()
        self._queue.append(req)

    def run(self) -> List[Request]:
        """Serve all queued requests to completion; returns finished list."""
        active: Dict[int, Request] = {}
        done: List[Request] = []
        pending = list(self._queue)
        self._queue.clear()
        tokens_left: Dict[int, int] = {}
        last_tok: Dict[int, np.ndarray] = {}

        while pending or active:
            # Admit: one request per client at a time (client independence —
            # a client's own requests serialize; different clients don't).
            for req in list(pending):
                if req.client_id not in active:
                    pending.remove(req)
                    active[req.client_id] = req
                    self._do_prefill(req, last_tok, tokens_left)

            # Batched decode tick over clients with work ready.
            ready = [c for c in active if tokens_left[c] > 0]
            if ready:
                self._decode_tick(ready, last_tok, tokens_left, active)

            for c in list(active):
                if tokens_left[c] == 0:
                    req = active.pop(c)
                    req.finish_t = time.perf_counter()
                    done.append(req)
        return done

    # ------------------------------------------------------------------
    def _do_prefill(self, req: Request, last_tok, tokens_left):
        """Prefill a single client (padded into the bank-wide call)."""
        c = req.client_id
        B, S = req.prompt.shape
        toks = np.zeros((self.n_clients, self.max_b, S), np.int32)
        toks[c, :B] = req.prompt
        logits, new_caches = self._prefill(self.base, self.bank, self.caches,
                                           {"tokens": jnp.asarray(toks)})
        # Only client c's cache entries advance.
        self.caches = jax.tree.map(
            lambda old, new: new.at[jnp.arange(self.n_clients) != c].set(
                old[jnp.arange(self.n_clients) != c])
            if old.ndim > 0 and old.shape[0] == self.n_clients else new,
            self.caches, new_caches)
        first = np.asarray(jnp.argmax(logits[c], axis=-1), np.int32)  # [max_b]
        req.generated = np.zeros((B, req.max_new_tokens), np.int32)
        req.generated[:, 0] = first[:B]
        last_tok[c] = first
        tokens_left[c] = req.max_new_tokens - 1
        if tokens_left[c] == 0:
            tokens_left[c] = 0

    def _decode_tick(self, ready: List[int], last_tok, tokens_left, active):
        toks = np.zeros((self.n_clients, self.max_b), np.int32)
        for c in ready:
            toks[c] = last_tok[c]
        logits, new_caches = self._decode(self.base, self.bank, self.caches,
                                          jnp.asarray(toks))
        ready_arr = np.zeros((self.n_clients,), bool)
        ready_arr[ready] = True
        sel = jnp.asarray(ready_arr)

        def merge(old, new):
            if old.ndim > 0 and old.shape[0] == self.n_clients:
                shape = (self.n_clients,) + (1,) * (old.ndim - 1)
                return jnp.where(sel.reshape(shape), new, old)
            return new

        self.caches = jax.tree.map(merge, self.caches, new_caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # [C, max_b]
        for c in ready:
            req = active[c]
            pos = req.max_new_tokens - tokens_left[c]
            req.generated[:, pos] = nxt[c, :req.generated.shape[0]]
            last_tok[c] = nxt[c]
            tokens_left[c] -= 1
        self.stats["ticks"] += 1
        self.stats["decode_tokens"] += len(ready)
        self.stats["batched_clients"] += len(ready)

    # ------------------------------------------------------------------
    def simulate_policy(self, requests: List[Request], *, policy: str = None,
                        exec_overhead: float = 1e-4, per_token_cost: float = 1e-6,
                        client_side_time: float = 5e-5):
        """Scheduler-simulated timeline for these requests under a policy
        (Tables 4/5 reproduction; real outputs are policy-invariant)."""
        policy = policy or self.scfg.policy
        clients = [ClientSpec(client_id=r.client_id,
                              n_tokens=int(r.prompt.shape[0]),
                              client_side_time=client_side_time,
                              n_iterations=r.max_new_tokens,
                              latency_sensitive=r.latency_sensitive)
                   for r in requests]
        return simulate(clients, self.cfg.n_layers, policy,
                        exec_overhead, per_token_cost,
                        wait_fraction=self.scfg.wait_fraction)
