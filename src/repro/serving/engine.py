"""Continuous-batching multi-client serving engine (paper §3.7 / §4.4).

Drives real model execution for a bank of inference clients that share one
frozen base. The engine realizes the paper's opportunistic-batching claim —
"requests batched at the first layer need not batch at later layers" — as a
live system rather than an offline simulation:

Architecture
------------
* **Slots.** Each client owns ``max_batch_per_client`` sequence slots backed
  by its rows of the bank KV/state cache. A request occupies one slot per
  prompt row for its lifetime; slots free the moment their request finishes
  and are re-admitted from the queue on the next tick — not after the whole
  bank drains (mid-stream join/leave).
* **Admission.** A per-engine FIFO queue. A request is admitted when (a) its
  client has enough free slots, (b) its context fits the cache depth, and
  (c) the optional ``PlacementRouter`` finds it a §3.4 placement (capacity
  is released on finish). Admission triggers the *masked single-client
  prefill* (``symbiosis.make_client_prefill``): one model execution for the
  admitted client, scattered into the bank cache under a slot mask — the
  seed engine instead ran a bank-wide prefill, paying C× base compute per
  admitted request.
* **Tick loop.** Every tick the scheduler policy (``core.scheduler.
  TickPolicy`` — lockstep / nolockstep / opportunistic) picks which *ready*
  clients join the batched decode (``symbiosis.make_masked_decode_step``);
  slots outside the tick keep their cache and position untouched inside the
  jitted step.
* **Sampling.** Greedy, temperature and top-k sampling, seeded per request
  (np.random.Generator keyed on the request's sampling seed + client id),
  so draws depend only on the request's own token stream.
* **Policy-invariance contract.** The policy (and any interleaving of other
  clients) only changes WHICH ready clients execute a given tick, never the
  math of a sequence's own stream — outputs are byte-identical across
  policies and to serving each request alone (paper: "the output with
  Symbiosis is exactly identical to that of the baseline"); asserted in
  tests/test_serving_engine.py.

For latency realism the engine also reports a scheduler-simulated timeline
(``simulate_policy``) calibrated with measured per-op costs.

Seed-engine ablation knobs: ``bank_prefill=True`` restores the bank-wide
prefill path and ``max_inflight_per_client=1`` the one-request-per-client
admission rule — used by benchmarks/bench_multiclient.py to quantify what
continuous batching buys over the seed behaviour.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AdapterConfig, ModelConfig, ServeConfig, DENSE, MOE, VLM
from repro.core import symbiosis
from repro.core.scheduler import ClientSpec, TickPolicy, simulate


# Jitted step builders are memoized on the (frozen, hashable) configs so
# every engine instance over the same model shares one compile cache —
# constructing an engine is cheap and benchmarks don't re-pay compilation.
@functools.lru_cache(maxsize=None)
def _jit_client_prefill(cfg, acfg, scfg):
    return jax.jit(symbiosis.make_client_prefill(cfg, acfg, scfg))


@functools.lru_cache(maxsize=None)
def _jit_masked_decode(cfg, acfg, scfg):
    return jax.jit(symbiosis.make_masked_decode_step(cfg, acfg, scfg))


@functools.lru_cache(maxsize=None)
def _jit_bank_prefill(cfg, acfg, scfg):
    return jax.jit(symbiosis.make_multi_client_prefill(cfg, acfg, scfg))


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling config. ``seed`` keys the request's private RNG:
    draws are consumed in token order of the request's own stream, so
    sampled outputs (not just greedy) are schedule/policy-invariant."""
    method: str = "greedy"            # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass(eq=False)       # identity eq: queues hold np arrays
class Request:
    client_id: int
    prompt: np.ndarray                      # [B, S] int32 (B sequence slots)
    max_new_tokens: int = 16
    latency_sensitive: bool = True
    sampling: Optional[SamplingParams] = None   # None -> greedy
    arrive_tick: int = 0                    # earliest tick admission may see it
    # filled by the engine:
    generated: Optional[np.ndarray] = None  # [B, max_new_tokens]
    submit_t: float = 0.0
    finish_t: float = 0.0


class ServingEngine:
    """One base model continuously serving a bank of adapter clients."""

    def __init__(self, cfg: ModelConfig, acfg: AdapterConfig, scfg: ServeConfig,
                 base_params, client_bank, *, max_batch_per_client: int = 4,
                 router=None, policy: Optional[str] = None,
                 bank_prefill: bool = False,
                 max_inflight_per_client: Optional[int] = None):
        self.cfg, self.acfg, self.scfg = cfg, acfg, scfg
        self.base = base_params
        self.bank = client_bank
        self.n_clients = jax.tree.leaves(client_bank)[0].shape[0]
        self.max_b = max_batch_per_client
        self.router = router
        self.policy = TickPolicy(policy or scfg.policy)
        self.bank_prefill = bank_prefill
        if bank_prefill and max_inflight_per_client not in (None, 1):
            raise ValueError("bank_prefill replaces the whole client cache "
                             "slice; it requires max_inflight_per_client=1")
        self.max_inflight = 1 if bank_prefill else max_inflight_per_client
        self.caches = symbiosis.init_client_caches(
            cfg, self.n_clients, max_batch_per_client, scfg.max_seq)
        self._prefill_one = _jit_client_prefill(cfg, acfg, scfg)
        self._prefill_bank = _jit_bank_prefill(cfg, acfg, scfg) if bank_prefill else None
        self._decode = _jit_masked_decode(cfg, acfg, scfg)
        self._queue: List[Request] = []
        # slot tables + per-request bookkeeping (keyed by id(req); requests
        # stay alive in the done list for the whole run)
        self._slot_owner = [[None] * self.max_b for _ in range(self.n_clients)]
        self._last_tok = np.zeros((self.n_clients, self.max_b), np.int32)
        self._left: Dict[int, int] = {}
        self._slots_of: Dict[int, List[int]] = {}
        self._rng: Dict[int, np.random.Generator] = {}
        self._placement: Dict[int, object] = {}
        self.stats = {"ticks": 0, "decode_tokens": 0, "prefill_tokens": 0,
                      "batched_clients": 0, "admitted": 0, "prefill_calls": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        assert 0 <= req.client_id < self.n_clients
        B, S = req.prompt.shape
        assert B <= self.max_b, f"request rows {B} > {self.max_b} slots"
        assert req.max_new_tokens >= 1
        assert S + req.max_new_tokens <= self.scfg.max_seq, (
            f"context {S}+{req.max_new_tokens} exceeds cache depth "
            f"{self.scfg.max_seq}")
        if req.sampling is not None and req.sampling.method not in (
                "greedy", "temperature", "top_k"):
            raise ValueError(f"unknown sampling method {req.sampling.method!r}")
        req.submit_t = time.perf_counter()
        self._queue.append(req)

    def run(self) -> List[Request]:
        """Serve all queued requests to completion; returns finished list."""
        waiting = deque(sorted(self._queue, key=lambda r: r.arrive_tick))
        self._queue.clear()
        inflight: List[Request] = []
        done: List[Request] = []
        tick = 0
        while waiting or inflight:
            # -- admission (continuous except under lockstep's batch barrier)
            admitted_any = False
            attempted = [r for r in waiting if r.arrive_tick <= tick]
            if self.policy.admit_now(len(inflight)):
                for req in attempted:
                    if self._try_admit(req):
                        waiting.remove(req)
                        inflight.append(req)
                        admitted_any = True

            # -- decode tick over the policy-chosen subset of ready clients
            ready = sorted({r.client_id for r in inflight if self._left[id(r)] > 0})
            serve = self.policy.serving_set(ready)
            if serve:
                self._decode_tick(set(serve), inflight)

            # -- retire finished sequences; their slots free immediately
            for req in list(inflight):
                if self._left[id(req)] == 0:
                    self._retire(req)
                    inflight.remove(req)
                    done.append(req)

            if not inflight and attempted and not admitted_any and not serve:
                # nothing in flight to ever free capacity, and admission of
                # every due request just failed -> stuck forever
                raise RuntimeError(
                    f"{len(attempted)} request(s) can never be admitted "
                    f"(no free capacity and nothing in flight)")
            tick += 1
            if not inflight and waiting and all(r.arrive_tick > tick for r in waiting):
                tick = min(r.arrive_tick for r in waiting)       # idle skip
        return done

    # ------------------------------------------------------------------
    # admission + prefill
    # ------------------------------------------------------------------
    def _try_admit(self, req: Request) -> bool:
        c = req.client_id
        B, S = req.prompt.shape
        if self.max_inflight is not None:
            owners = {id(o) for o in self._slot_owner[c] if o is not None}
            if len(owners) >= self.max_inflight:
                return False
        free = [s for s in range(self.max_b) if self._slot_owner[c][s] is None]
        if len(free) < B:
            return False
        placement = None
        if self.router is not None:
            try:
                placement = self.router.route(S + req.max_new_tokens, B,
                                              latency_sensitive=req.latency_sensitive)
            except RuntimeError:
                return False                      # stays queued until capacity frees
        slots = free[:B]
        first_logits = self._prefill_request(req, slots)

        sp = req.sampling or SamplingParams()
        self._rng[id(req)] = np.random.default_rng([sp.seed, c])
        first = self._sample(first_logits, req)
        req.generated = np.zeros((B, req.max_new_tokens), np.int32)
        req.generated[:, 0] = first
        self._last_tok[c, slots] = first
        self._left[id(req)] = req.max_new_tokens - 1
        self._slots_of[id(req)] = slots
        self._placement[id(req)] = placement
        for s in slots:
            self._slot_owner[c][s] = req
        self.stats["admitted"] += 1
        self.stats["prefill_calls"] += 1
        self.stats["prefill_tokens"] += B * S
        return True

    def _bucket(self, S: int) -> int:
        """Jit-bucketed prompt length. Attention families tolerate right-
        padding exactly (see model.prefill); recurrent families (hybrid,
        RWKV) must prefill at true length or pads pollute the state."""
        if self.cfg.arch not in (DENSE, MOE, VLM):
            return S
        b = 8
        while b < S:
            b *= 2
        return min(b, self.scfg.max_seq)

    def _prefill_request(self, req: Request, slots: List[int]) -> np.ndarray:
        """Masked single-client prefill into the assigned slots.

        Returns the [B, V] logits of the prompt's last position per row."""
        c = req.client_id
        B, S = req.prompt.shape
        if self.bank_prefill:
            return self._prefill_request_bankwide(req, slots)
        S_pad = self._bucket(S)
        toks = np.zeros((self.max_b, S_pad), np.int32)
        toks[slots, :S] = req.prompt
        mask = np.zeros((self.max_b,), bool)
        mask[slots] = True
        lengths = np.full((self.max_b,), S, np.int32)
        logits, self.caches = self._prefill_one(
            self.base, self.bank, self.caches, np.int32(c),
            jnp.asarray(toks), jnp.asarray(lengths), jnp.asarray(mask))
        return np.asarray(logits)[slots]

    def _prefill_request_bankwide(self, req: Request, slots: List[int]) -> np.ndarray:
        """Seed-engine ablation: pad the request into a bank-wide [C, max_b,
        S] prefill (C× the base compute of the masked path) and replace the
        whole client cache slice."""
        c = req.client_id
        B, S = req.prompt.shape
        toks = np.zeros((self.n_clients, self.max_b, S), np.int32)
        toks[c, slots] = req.prompt
        logits, new_caches = self._prefill_bank(self.base, self.bank, self.caches,
                                               {"tokens": jnp.asarray(toks)})
        sel = np.zeros((self.n_clients,), bool)
        sel[c] = True
        sel = jnp.asarray(sel)

        def merge(old, new):
            return jnp.where(sel.reshape((self.n_clients,) + (1,) * (old.ndim - 1)),
                             new, old)

        self.caches = jax.tree.map(merge, self.caches, new_caches)
        return np.asarray(logits)[c, slots]

    # ------------------------------------------------------------------
    # decode + sampling
    # ------------------------------------------------------------------
    def _decode_tick(self, serve: set, inflight: List[Request]):
        active = np.zeros((self.n_clients, self.max_b), bool)
        stepping = [r for r in inflight
                    if r.client_id in serve and self._left[id(r)] > 0]
        for req in stepping:
            active[req.client_id, self._slots_of[id(req)]] = True
        logits, self.caches = self._decode(
            self.base, self.bank, self.caches,
            jnp.asarray(self._last_tok), jnp.asarray(active))
        lg = np.asarray(logits)
        for req in stepping:
            c, slots = req.client_id, self._slots_of[id(req)]
            nxt = self._sample(lg[c, slots], req)
            pos = req.max_new_tokens - self._left[id(req)]
            req.generated[:, pos] = nxt
            self._last_tok[c, slots] = nxt
            self._left[id(req)] -= 1
            self.stats["decode_tokens"] += len(slots)
        self.stats["ticks"] += 1
        self.stats["batched_clients"] += len(serve)

    def _sample(self, logits: np.ndarray, req: Request) -> np.ndarray:
        """logits [rows, V] -> next token per row, via the request's RNG."""
        sp = req.sampling
        if sp is None or sp.method == "greedy":
            return np.argmax(logits, axis=-1).astype(np.int32)
        if sp.method not in ("temperature", "top_k"):
            raise ValueError(f"unknown sampling method {sp.method!r}")
        z = logits.astype(np.float64) / max(sp.temperature, 1e-6)
        k = min(sp.top_k, z.shape[-1])          # top_k > vocab = no truncation
        if sp.method == "top_k" and k > 0:
            kth = np.partition(z, -k, axis=-1)[:, -k][:, None]
            z = np.where(z < kth, -np.inf, z)
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        rng = self._rng[id(req)]
        return np.array([rng.choice(p.shape[-1], p=row) for row in p], np.int32)

    def _retire(self, req: Request):
        req.finish_t = time.perf_counter()
        for s in self._slots_of.pop(id(req)):
            self._slot_owner[req.client_id][s] = None
        del self._left[id(req)]
        self._rng.pop(id(req), None)
        placement = self._placement.pop(id(req), None)
        if placement is not None:
            self.router.release(placement)

    # ------------------------------------------------------------------
    def simulate_policy(self, requests: List[Request], *, policy: str = None,
                        exec_overhead: float = 1e-4, per_token_cost: float = 1e-6,
                        client_side_time: float = 5e-5):
        """Scheduler-simulated timeline for these requests under a policy
        (Tables 4/5 reproduction; real outputs are policy-invariant)."""
        policy = policy or self.policy.name
        clients = [ClientSpec(client_id=r.client_id,
                              n_tokens=int(r.prompt.shape[0]),
                              client_side_time=client_side_time,
                              n_iterations=r.max_new_tokens,
                              latency_sensitive=r.latency_sensitive)
                   for r in requests]
        return simulate(clients, self.cfg.n_layers, policy,
                        exec_overhead, per_token_cost,
                        wait_fraction=self.scfg.wait_fraction)
