"""KV-cache management for multi-client serving.

Per the Symbiosis split, KV caches are *client-side* runtime state — they
never live with the base executor (paper §1: "the base executor is
stateless"). This module provides:

* ``CacheSpec`` / ``cache_bytes`` — sizing logic used by the engine's
  admission control and by the heterogeneous-placement cost model (§3.4):
  whether a client's cache fits on-device or must be host-offloaded.
  ``serving.engine`` admits a request only if its full context
  (prompt + max_new_tokens) fits the slot depth, and the optional
  ``PlacementRouter`` charges ``cache_bytes`` against fleet HBM for the
  request's lifetime (released when its slots free). The model is
  layout-aware: ``quant=True`` prices int8 entries + per-head f32 scales,
  and ``page_block > 0`` prices the PAGED layout — bytes are charged per
  allocated ``page_block``-token page (the request's context rounded up to
  whole pages) instead of per dense ``max_seq``-deep slot row, which is
  what lets many short requests share the HBM one dense row used to pin.
* sliding-window ring-buffer cache ops (the beyond-paper long-context
  variant for dense archs).
* host-offload accounting: on real TPU hardware the cache is placed with
  ``jax.device_put(..., TransferToMemoryKind("pinned_host"))``; in this CPU
  container we model placement analytically (bytes + PCIe transfer terms),
  which is what the Fig 19 reproduction uses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RWKV, HYBRID, ENCDEC
from repro.common.hardware import V5E


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Shape/bytes description of one client's decode state."""
    kind: str                    # "kv" | "rwkv" | "hybrid" | "encdec"
    bytes_per_token: int         # marginal HBM per generated/context token
    fixed_bytes: int             # state independent of seq len (SSM state etc.)

    def total_bytes(self, seq_len: int, batch: int) -> int:
        return self.fixed_bytes * batch + self.bytes_per_token * seq_len * batch


def _dt_bytes(cfg: ModelConfig) -> int:
    return jnp.dtype(cfg.dtype).itemsize


def make_cache_spec(cfg: ModelConfig, *, quant: bool = False) -> CacheSpec:
    """Derive the decode-state spec from a model config.

    ``quant=True`` prices the int8 KV layout: 1-byte entries plus one f32
    scale per head per token for K and V each (pure-KV families only; the
    recurrent/hybrid fixed state is never quantized)."""
    it = _dt_bytes(cfg)
    kv_row = cfg.n_kv_heads * cfg.hd * it * 2          # K+V per layer per token
    if quant:
        kv_row = cfg.n_kv_heads * (cfg.hd * 1 + 4) * 2  # int8 entries + f32 scale
    if cfg.arch == RWKV:
        H = cfg.d_model // cfg.hd
        fixed = cfg.n_layers * (H * cfg.hd * cfg.hd * 4      # wkv state f32
                                + 2 * cfg.d_model * it)      # shift tails
        return CacheSpec("rwkv", 0, fixed)
    if cfg.arch == HYBRID:
        n_attn = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.n_layers - n_attn
        ed = cfg.mamba_expand * cfg.d_model
        fixed = n_mamba * (ed * cfg.d_state * 4 + (cfg.d_conv - 1) * ed * 4)
        return CacheSpec("hybrid", n_attn * kv_row, fixed)
    if cfg.arch == ENCDEC:
        fixed = cfg.n_layers * cfg.n_frontend_tokens * kv_row  # cross-attn cache
        return CacheSpec("encdec", cfg.n_layers * kv_row, fixed)
    per_tok = cfg.n_layers * kv_row
    return CacheSpec("kv", per_tok, 0)


def fits_hbm(cfg: ModelConfig, seq_len: int, batch: int, *, chip=V5E,
             reserved_fraction: float = 0.35) -> bool:
    """Admission check: does this client's cache fit beside its share of the
    base? ``reserved_fraction`` approximates base weights + activations."""
    spec = make_cache_spec(cfg)
    return spec.total_bytes(seq_len, batch) < chip.hbm_bytes * (1 - reserved_fraction)


# ---------------------------------------------------------------------------
# Sliding-window ring-buffer cache (beyond-paper dense long-context variant)
# ---------------------------------------------------------------------------

def ring_cache_init(cfg: ModelConfig, batch: int, window: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, window, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((L, batch, window, cfg.n_kv_heads, cfg.hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def ring_write(cache_k, cache_v, k, v, pos, window: int):
    """Write one token's K/V at slot pos % window. k/v [B,1,K,hd]; pos [B]."""
    slot = pos % window
    idx = slot[:, None, None, None]
    t_iota = jnp.arange(window)[None, :, None, None]
    write = t_iota == idx
    return jnp.where(write, k, cache_k), jnp.where(write, v, cache_v)


def ring_valid_mask(pos, window: int):
    """[B, window] mask of live slots + their absolute positions.

    Slot s holds absolute position p where p % window == s and p <= pos and
    p > pos - window. Returns (mask [B,W] bool, abs_pos [B,W] int32)."""
    s = jnp.arange(window)[None, :]
    cycle = (pos[:, None] - s) // window
    abs_pos = cycle * window + s
    mask = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    return mask, abs_pos


# ---------------------------------------------------------------------------
# Host-offload placement model (paper §3.4 / Fig 19 reproduction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementCost:
    """Per-decode-token latency terms for one client placement (seconds)."""
    compute: float
    transfer: float

    @property
    def total(self):
        return self.compute + self.transfer


def decode_token_cost(cfg: ModelConfig, seq_len: int, *, placement: str,
                      chip=V5E) -> PlacementCost:
    """Analytic per-token decode cost for the §3.4 placements.

    placement:
      'gpu'          — cache + attention on accelerator (fails if cache > HBM)
      'gpu_offload'  — cache on host, attention on accelerator: the *whole
                       window's* K/V crosses PCIe every token (the paper's
                       second baseline; cost grows linearly with context)
      'hetero'       — Symbiosis: cache AND attention on host; only the
                       activations cross PCIe (constant per token), attention
                       runs at host FLOP/s

    Base-layer (linear) compute is identical across placements — it stays on
    the accelerator in all three — so it is excluded (it cancels in the
    comparison; Fig 19 plots inter-token latency dominated by attention).
    """
    spec = make_cache_spec(cfg)
    cache_bytes_total = spec.bytes_per_token * seq_len + spec.fixed_bytes
    # attention flops per token: 2 ops (QK^T, PV) * 2 MAC = 4 * L * H * hd * S
    attn_flops = 4.0 * cfg.n_layers * cfg.n_heads * cfg.hd * seq_len
    act_bytes = cfg.n_layers * cfg.d_model * _dt_bytes(cfg) * 2  # to/from per layer

    if placement == "gpu":
        if cache_bytes_total > chip.hbm_bytes * 0.65:
            return PlacementCost(compute=float("inf"), transfer=0.0)  # OOM
        # HBM-bound: read the whole cache per token.
        return PlacementCost(compute=cache_bytes_total / chip.hbm_bandwidth,
                             transfer=0.0)
    if placement == "gpu_offload":
        return PlacementCost(compute=cache_bytes_total / chip.hbm_bandwidth,
                             transfer=cache_bytes_total / chip.pcie_bandwidth)
    if placement == "hetero":
        # host attention is bound by max(CPU flops, DRAM cache read)
        compute = max(attn_flops / chip.host_flops,
                      cache_bytes_total / chip.host_mem_bandwidth)
        return PlacementCost(compute=compute,
                             transfer=act_bytes / chip.pcie_bandwidth)
    raise ValueError(placement)


def cache_bytes(cfg: ModelConfig, seq_len: int, batch: int = 1, *,
                quant: bool = False, page_block: int = 0) -> int:
    """HBM bytes of one client's decode state for ``seq_len`` context.

    ``page_block > 0`` charges the paged layout: the context is rounded up
    to whole pages (what the engine's allocator actually pins), instead of
    the caller pre-rounding to a dense ``max_seq`` row."""
    if page_block:
        seq_len = -(-seq_len // page_block) * page_block
    return make_cache_spec(cfg, quant=quant).total_bytes(seq_len, batch)
