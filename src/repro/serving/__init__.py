from repro.serving.kvcache import cache_bytes, CacheSpec, make_cache_spec
from repro.core.engine_spec import BankSpec, EngineSpec
from repro.serving.engine import ServingEngine, Request, SamplingParams
from repro.serving.router import PlacementRouter, Slot, Placement
