"""AdamW in pure JAX (the fine-tuning client's optimizer).

Per the Symbiosis design, optimizer state is *client-side* runtime state: in
multi-client banks every state leaf carries a leading client axis and the
update is vmapped (core.symbiosis), so each client tunes independently while
sharing the frozen base.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def adamw_init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), t)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state: AdamWState, lr, *, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, max_grad_norm=0.0):
    if max_grad_norm:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e30)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def adamw_update_hyper(params, grads, state: AdamWState, lr, weight_decay,
                       max_grad_norm, *, b1=0.9, b2=0.999, eps=1e-8):
    """``adamw_update`` with TRACED per-call hyperparameters.

    The multi-job train step (core.symbiosis.make_compact_train_step) runs a
    bank of jobs whose lr / weight-decay / clip settings differ PER ROW, so
    they arrive as traced scalars and the Python conditionals of
    ``adamw_update`` can't branch on them. This variant applies the clip
    scale and the decay term unconditionally — which is bitwise-equal to the
    conditional form at every setting: "no clip" is encoded as
    ``max_grad_norm = inf`` (scale is exactly 1.0 and ``g * 1.0 == g``), and
    ``weight_decay = 0.0`` contributes exactly ``u + 0.0 * p == u``. That
    equivalence is what lets a bank row match its dedicated
    ``make_baseline_train_step`` run bit-for-bit while other rows use
    different hyperparameters.
    """
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
