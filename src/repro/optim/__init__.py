from repro.optim.adamw import (adamw_init, adamw_update, adamw_update_hyper,
                               clip_by_global_norm)
from repro.optim.schedules import warmup_cosine
