"""command-r-35b — dense, GQA (64H/8KV), no-bias.
[hf:CohereForAI/c4ai-command-r-v01] 40L d_model=8192 d_ff=22528 vocab=256000.
long_500k skipped (full attention; see DESIGN.md §6)."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="command-r-35b",
    arch=DENSE,
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256_000,
    source="hf:CohereForAI/c4ai-command-r-v01 (GQA, no-bias)",
)
