"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
every 2nd layer. [arXiv:2403.19887] 32L d_model=4096 32H(kv=8) d_ff=14336
vocab=65536. long_500k RUNS (KV cache only for the 4 attention layers)."""
from repro.config import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch=HYBRID,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,            # MoE FFN every 2nd sublayer...
    moe_offset=1,           # ...on odd positions within the period
    attn_every=8,           # attention on sublayer 7 of each 8-layer period
    d_state=16,
    d_conv=4,
    mamba_expand=2,
    source="arXiv:2403.19887 (Jamba: 1:7 attn:mamba, MoE every 2)",
)
