"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP in parallel.
[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H(kv=8)
d_expert=4864 vocab=32000. Largest assigned config; stresses
expert-parallel sharding + compile-time memory fit.
long_500k skipped (full attention)."""
from repro.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="arctic-480b",
    arch=MOE,
    n_layers=35,
    d_model=7168,
    n_heads=56,
    head_pad=8,             # §Perf it5: zero-weight pad 56->64 q-heads so
                            # attention shards 16-way (exact; see DESIGN.md)
    n_kv_heads=8,
    d_ff=4864,
    d_expert=4864,
    vocab=32_000,
    n_experts=128,
    top_k=2,
    dense_residual=True,    # Arctic: dense MLP in parallel with the MoE FFN
    moe_every=1,
    source="hf:Snowflake/snowflake-arctic-base (dense-MoE hybrid residual)",
)
