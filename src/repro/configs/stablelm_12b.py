"""stablelm-12b — dense, GQA (32H/8KV).
[hf:stabilityai/stablelm-2-1_6b family] 40L d_model=5120 d_ff=13824 vocab=100352.
long_500k skipped (full attention)."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch=DENSE,
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100_352,
    source="hf:stabilityai/stablelm-2-1_6b (scaled family member)",
)
