"""llava-next-mistral-7b — VLM: Mistral-7B dense backbone consuming anyres
patch embeddings from a STUBBED ViT/projector frontend.
[hf:llava-hf/llava-v1.6-mistral-7b-hf] 32L d_model=4096 32H(kv=8) d_ff=14336
vocab=32000; 2880 image tokens (anyres 2x2 grid + base, 576 each).
long_500k skipped (full attention)."""
from repro.config import ModelConfig, VLM

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch=VLM,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32_000,
    n_frontend_tokens=2880,  # anyres: 5 tiles x 576 patches (stubbed ViT)
    sliding_window=4096,     # Mistral-style SWA
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (anyres tiling, stub ViT)",
)
