"""rwkv6-7b — Finch: attention-free SSM with data-dependent decay.
[arXiv:2404.05892] 32L d_model=4096 d_ff=14336 vocab=65536, head size 64.
long_500k runs natively (O(1) recurrent state)."""
from repro.config import ModelConfig, RWKV

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch=RWKV,
    n_layers=32,
    d_model=4096,
    n_heads=64,           # d_model / head_size(64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    source="arXiv:2404.05892 (RWKV6 'Finch', data-dependent decay)",
)
