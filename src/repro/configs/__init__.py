"""Assigned-architecture configs (+ the paper's own eval model).

``get_config(arch_id)`` resolves the ``--arch`` CLI flag; every config cites
its source in ``CONFIG.source``.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

# arch-id -> module name
ARCHS = {
    "rwkv6-7b": "rwkv6_7b",
    "command-r-35b": "command_r_35b",
    "stablelm-12b": "stablelm_12b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen3-4b": "qwen3_4b",
    "granite-3-8b": "granite_3_8b",
    "arctic-480b": "arctic_480b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "whisper-small": "whisper_small",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    # the paper's own eval models (Table 3) — not in the assigned pool
    "symbiosis-llama2-13b": "symbiosis_llama2_13b",
    "gemma2-27b": "gemma2_27b",
    "starcoder2-15b": "starcoder2_15b",
}

_PAPER_EXTRAS = {"symbiosis-llama2-13b", "gemma2-27b", "starcoder2-15b"}
ASSIGNED = [a for a in ARCHS if a not in _PAPER_EXTRAS]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCHS}
