"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6,
first layer dense. [arXiv:2401.06066] 28L d_model=2048 16H(kv=16)
d_expert=1408 vocab=102400. long_500k skipped (full attention)."""
from repro.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch=MOE,
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # MHA (kv=16)
    d_ff=1408,              # per-expert hidden (fine-grained)
    d_expert=1408,
    vocab=102_400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,   # layer 0 uses a dense FFN (paper-faithful)
    moe_every=1,
    source="arXiv:2401.06066 (DeepSeekMoE: fine-grained + shared experts)",
)
