"""starcoder2-15b — paper Table 3 eval model (60 GB fp32 in the paper's
remote-execution experiment, §4.2.2). Dense, GQA (48H/4KV).
[paper Table 3 / hf:bigcode/starcoder2-15b] Not in the assigned pool."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch=DENSE,
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49_152,
    sliding_window=4096,
    source="paper Table 3 (Starcoder2-15B; remote-execution eval §4.2.2)",
)
