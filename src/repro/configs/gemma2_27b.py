"""gemma2-27b — the paper's largest eval model (Table 3: 56 GB, 46 layers,
sharded-remote config in Fig 17). Dense, GQA (32H/16KV), wide FFN.
[paper Table 3 / hf:google/gemma-2-27b] Not in the assigned pool — included
to mirror the paper's own eval set (logit softcapping omitted; noted)."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch=DENSE,
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256_128,
    sliding_window=4096,     # gemma2 alternates local/global; modeled as SWA
    source="paper Table 3 (Gemma2-27B; Fig 17 sharded-remote eval)",
)
