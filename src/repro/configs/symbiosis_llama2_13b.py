"""symbiosis-llama2-13b — the paper's own primary evaluation model
(Table 3: Llama2-13B, 26 GB, 40 layers). Used by the paper-table benchmarks;
not part of the assigned-architecture pool."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="symbiosis-llama2-13b",
    arch=DENSE,
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,          # Llama2 is MHA
    d_ff=13824,
    vocab=32_000,
    source="paper Table 3 (Llama2-13B, the main Symbiosis eval model)",
)
