"""whisper-small — encoder-decoder audio backbone (conv/mel frontend is a
STUB: input_specs provides 1500 precomputed frame embeddings).
[arXiv:2212.04356] 12L enc + 12L dec, d_model=768 12H(kv=12, MHA) d_ff=3072
vocab=51865, GELU MLP with bias, learned positions (rope_theta=0)."""
from repro.config import ModelConfig, ENCDEC

CONFIG = ModelConfig(
    name="whisper-small",
    arch=ENCDEC,
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,          # MHA
    d_ff=3072,
    vocab=51_865,
    n_frontend_tokens=1500, # 30 s of audio at 50 frames/s (stubbed frontend)
    rope_theta=0.0,         # learned absolute positions, Whisper-faithful
    source="arXiv:2212.04356 (Whisper; enc-dec, conv frontend stubbed)",
)
