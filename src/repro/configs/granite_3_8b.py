"""granite-3-8b — dense, GQA (32H/8KV).
[hf:ibm-granite/granite-3.0-2b-base family] 40L d_model=4096 d_ff=12800
vocab=49155. long_500k skipped (full attention)."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="granite-3-8b",
    arch=DENSE,
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49_155,
    source="hf:ibm-granite/granite-3.0-2b-base (8b sibling config)",
)
