"""qwen3-4b — dense, GQA (32H/8KV), qk-norm, head_dim=128.
[hf:Qwen/Qwen3-8B family] 36L d_model=2560 d_ff=9728 vocab=151936.
long_500k skipped (full attention)."""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen3-4b",
    arch=DENSE,
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,           # decoupled head dim (Qwen3)
    d_ff=9728,
    vocab=151_936,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B (qk_norm, GQA; 4B sibling config)",
)
