"""Deterministic synthetic LM data pipeline.

The paper evaluates with randomly initialized input tensors ("the content of
the input is not relevant to the performance metrics", §4) — we do the same,
but make it a *real* pipeline: deterministic per-(client, step) streams, a
learnable k-th-order Markov structure (so fine-tuning loss actually
decreases and per-client convergence can be asserted in tests), document
packing to a fixed sequence length, and shard-aware slicing for the
data-parallel mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ENCDEC, VLM


@dataclasses.dataclass
class SyntheticLMDataset:
    """Per-client deterministic token streams with learnable structure.

    Each client c draws from its own order-1 Markov chain (transition matrix
    seeded by ``seed + c``), giving every fine-tuning job a distinct
    "task" — losses are comparable across steps but not across clients,
    like real multi-tenant adapters.
    """
    vocab: int
    seq_len: int
    n_clients: int
    batch_per_client: int
    seed: int = 0
    structure: float = 0.8     # prob mass on the preferred next-token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # one preferred-successor table per client: vocab -> vocab
        self.succ = rng.integers(0, self.vocab, size=(self.n_clients, self.vocab))

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Returns tokens/labels of shape [C, B, S] for one step."""
        C, B, S, V = self.n_clients, self.batch_per_client, self.seq_len, self.vocab
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((C, B, S + 1), np.int32)
        toks[:, :, 0] = rng.integers(0, V, size=(C, B))
        rand = rng.random((C, B, S))
        noise = rng.integers(0, V, size=(C, B, S))
        for t in range(S):
            preferred = np.take_along_axis(
                self.succ, toks[:, :, t].reshape(C, -1), axis=1).reshape(C, B)
            toks[:, :, t + 1] = np.where(rand[:, :, t] < self.structure,
                                         preferred, noise[:, :, t])
        return {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def frontend_stub(cfg: ModelConfig, n_clients: int, batch: int, *, seed: int = 0,
                  dtype=None) -> Dict[str, jnp.ndarray]:
    """Precomputed modality-frontend embeddings (the one allowed stub).

    audio: mel+conv frame embeddings [C, B, T_enc, d];
    vlm:   ViT/projector anyres patch embeddings [C, B, T_img, d].
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    key = jax.random.PRNGKey(seed)
    T = cfg.n_frontend_tokens
    emb = (jax.random.normal(key, (n_clients, batch, T, cfg.d_model), jnp.float32)
           * 0.02).astype(dtype)
    if cfg.arch == ENCDEC:
        return {"frames": emb}
    if cfg.arch == VLM:
        return {"img_embed": emb}
    return {}


def make_client_batches(cfg: ModelConfig, n_clients: int, batch_per_client: int,
                        seq_len: int, *, seed: int = 0) -> "ClientBatchStream":
    """Convenience: dataset + frontend stubs composed per model family."""
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=seq_len, n_clients=n_clients,
                            batch_per_client=batch_per_client, seed=seed)
    extra = frontend_stub(cfg, n_clients, batch_per_client, seed=seed)
    return ClientBatchStream(ds, extra)


class ClientBatchStream:
    def __init__(self, ds: SyntheticLMDataset, extra: Dict[str, jnp.ndarray]):
        self.ds = ds
        self.extra = extra

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        b = self.ds.batch(step)
        b.update(self.extra)     # frontend embeddings are static stand-ins
        return b
