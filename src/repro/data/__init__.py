from repro.data.pipeline import SyntheticLMDataset, make_client_batches, frontend_stub
