"""FinetuneEngine: fine-tuning as a service over one shared frozen base.

The training-side twin of ``serving.ServingEngine`` (paper §3, §5): tenants
``submit()`` ``FinetuneJob``s — each with its own PEFT method/rank/targets,
AdamW hyperparameters + warmup-cosine schedule, data stream and grad-accum
microbatching — and the engine time-shares ONE resident copy of the frozen
base params across all of them, admitting and retiring jobs mid-run.

Architecture
------------
* **Banks.** Jobs whose step programs can share one vmapped call — same
  ``AdapterConfig``, per-step batch shape and microbatch factor — are
  grouped into a bank: adapter params and AdamW state stacked along a
  leading bank-slot axis. Heterogeneous jobs (LoRA + IA3 + prefix, mixed
  ranks/batch shapes) form SEPARATE banks inside the same engine, all
  closing over the same base tree — the multi-bank heterogeneous-methods
  service the adapter ecosystem needs (LLM-Adapters), without replicating
  the base.
* **Bucketed membership.** A bank's capacity grows by doubling and each
  tick gathers the active slots into a power-of-two row bucket
  (``core.symbiosis.make_compact_train_step``), so join/leave churn causes
  a bounded number of recompiles and a sparse bank pays compute for its
  ACTIVE jobs, not its high-water mark.
* **Byte-identity.** A bank row runs the exact ``make_row_grad_fn``
  program its solo ``make_baseline_train_step`` oracle runs, and the
  scatter back into the bank only touches the gathered rows — so every
  job's per-step grads, adapter params and optimizer state match its
  dedicated run bit-for-bit, and churn around a job can never perturb it.
* **Admission.** Each tick scans the queue in submit order, gated by
  ``FinetuneConfig.max_jobs`` and (when a ``PlacementRouter`` is attached)
  by an HBM charge for what a job actually pins: adapter params + AdamW
  moments + an activation working-set estimate (``job_hbm_bytes``). A job
  that doesn't fit stays queued without blocking later jobs (the serving
  engine's continuous-admission rule); capacity releases at retire, and
  queued jobs take it on the next tick.
* **Retire / resume.** A job retires when its step budget is exhausted or
  on explicit ``retire()``; its ``JobResult`` carries the final adapter +
  optimizer state. Re-submitting that state (``init_adapter`` /
  ``init_opt`` / ``start_step``) continues the same trajectory bitwise —
  the checkpoint/resume story of a service whose clients own their state.

Driven standalone via ``run()``, or interleaved tick-by-tick with a
``ServingEngine`` against the same donated base by
``training.SymbiosisEngine``.

Observability (docs/observability.md): construct with ``obs=Obs()`` and
the engine emits tick-phase spans (admit / compact gather / train step /
device sync / scatter), per-job counters (``train_steps_total``,
``train_tokens_total``, ``train_loss``), and structured events (admit,
retire, backoff, retry, quarantine, compile) drainable via
``drain_events()``. Telemetry is strictly additive: with ``obs=None``
(the default) the hot path takes a no-op span and skips every metric
callback, and with it enabled all timestamps land at tick boundaries —
committed results stay bitwise identical either way.

Machine-checked invariants (docs/invariants.md): frozen-base taint (a
train step must never produce a base-shaped output that isn't a declared
update), donation of bank/optimizer state, per-row isolation, and closed
jit bucket coverage via ``trace_domain()`` +
``repro.analysis.tracecount.dispatch`` are enforced by
``python -m repro.analysis`` and tested in tests/test_analysis.py.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tracecount
from repro.config import AdapterConfig, FinetuneConfig, ModelConfig
from repro.core import adapters as adapters_lib
from repro.core import symbiosis
from repro.core.engine_spec import EngineSpec
from repro.faults.health import HealthPolicy, HealthRecord, classify
from repro.faults.plan import NonFiniteFault, StreamExhausted, TransientFault
from repro.optim import adamw_init
from repro.training.job import FinetuneJob, JobResult

# telemetry-off spans: one shared nullcontext, zero per-phase allocation
# (docs/observability.md — the disabled mode must cost nothing on the tick)
_NULL_CTX = contextlib.nullcontext()


def _null_span(name):
    return _NULL_CTX


def _pin_train(fn, cfg, mesh):
    """Sharded hot path: pin the donated bank/optimizer trees to their
    client-axis specs on the way IN and OUT of the jitted step (the
    training twin of ``serving.engine._pin_serving``) — donated state keeps
    ONE placement across ticks and the row gather/scatter never round-trips
    through a replicated layout. ``mesh=None`` returns ``fn`` untouched."""
    if mesh is None:
        return fn
    from repro.launch import shardings

    def pinned(base, bank, opt, batch, slots, row_mask, hyper):
        bank = shardings.bank_state_constrain(cfg, mesh, bank)
        opt = shardings.bank_state_constrain(cfg, mesh, opt)
        new_bank, new_opt, metrics = fn(base, bank, opt, batch, slots,
                                        row_mask, hyper)
        return (shardings.bank_state_constrain(cfg, mesh, new_bank),
                shardings.bank_state_constrain(cfg, mesh, new_opt), metrics)

    return pinned


# One compile cache per (model, adapter-config, step knobs) shared by every
# engine instance (``mesh`` joins the key — a sharded engine gets its own
# jitted wrapper); bank/opt (args 1, 2) are donated — the engine always
# rebinds them, so XLA updates the stacked job state in place.
@functools.lru_cache(maxsize=None)
def _jit_compact_train(cfg, acfg, microbatch, memory_optimized, remat,
                       mesh=None):
    return jax.jit(_pin_train(symbiosis.make_compact_train_step(
        cfg, acfg, microbatch=microbatch, memory_optimized=memory_optimized,
        remat=remat), cfg, mesh), donate_argnums=(1, 2))


@dataclasses.dataclass(frozen=True)
class BankKey:
    """Jobs sharing one vmapped step program: same PEFT config, same
    per-step batch shape, same grad-accum factor."""
    acfg: AdapterConfig
    batch: int
    seq: int
    microbatch: int


class _Bank:
    """One bank's stacked state. ``slots[i]`` is the occupying job (or
    None); params/opt leaves carry the matching leading [cap] axis.

    ``reserve`` (from ``BankSpec.capacity``) pre-sizes the first
    allocation: the stacked leaves come up at the next power of two >=
    reserve instead of growing 1 -> 2 -> 4 under churn. Row buckets are
    ``min(pow2(active), cap)`` either way, so a reserved bank runs the
    SAME bucketed programs as a doubling-grown one — byte-identity is
    unaffected; only the number of growth reallocations changes."""

    def __init__(self, key: BankKey, reserve: int = 0):
        self.key = key
        self.reserve = reserve
        self.params = None
        self.opt = None
        self.slots: List[Optional[FinetuneJob]] = []

    @property
    def cap(self) -> int:
        return len(self.slots)

    def alloc(self, adapter, opt_state) -> int:
        """Place one job's state into a free slot, growing cap 1 -> 2 -> 4
        ... by zero-padding the stacked leaves when the bank is full."""
        if None not in self.slots:
            if self.params is None:
                cap0 = 1
                while cap0 < self.reserve:
                    cap0 *= 2
                zero = lambda x: jnp.zeros((cap0,) + x.shape, x.dtype)
                self.params = jax.tree.map(zero, adapter)
                self.opt = jax.tree.map(zero, opt_state)
                self.slots = [None] * cap0
            else:
                grow = self.cap                      # double
                pad = lambda x: jnp.concatenate(
                    [x, jnp.zeros((grow,) + x.shape[1:], x.dtype)])
                self.params = jax.tree.map(pad, self.params)
                self.opt = jax.tree.map(pad, self.opt)
                self.slots.extend([None] * grow)
                return self._write(self.slots.index(None), adapter, opt_state)
            return self._write(0, adapter, opt_state)
        return self._write(self.slots.index(None), adapter, opt_state)

    def _write(self, slot, adapter, opt_state) -> int:
        wr = lambda full, one: full.at[slot].set(one.astype(full.dtype))
        self.params = jax.tree.map(wr, self.params, adapter)
        self.opt = jax.tree.map(wr, self.opt, opt_state)
        return slot

    def read(self, slot):
        return (jax.tree.map(lambda x: x[slot], self.params),
                jax.tree.map(lambda x: x[slot], self.opt))


def job_hbm_bytes(cfg: ModelConfig, job: FinetuneJob, *,
                  remat: bool = False) -> int:
    """Admission charge for one job: what fine-tuning actually pins beyond
    the (already-resident, shared) base — adapter params, the two f32 AdamW
    moment trees, and an activation working-set estimate (per-microbatch
    live tokens × residual stream, plus the logits block)."""
    n_params, adapter_b = adapters_lib.adapter_bytes(cfg, job.acfg)
    opt_b = 2 * n_params * 4
    nmb = max(1, job.microbatch)
    if job.batch_size % nmb or job.batch_size == nmb:
        nmb = 1     # make_row_grad_fn falls back to one full-batch grad —
        #             charge the activations the job will actually hold
    tokens = job.batch_size * job.seq_len // nmb
    layers_live = 2 if remat else cfg.n_layers
    act_b = 4 * tokens * (layers_live * cfg.d_model + cfg.vocab)
    return adapter_b + opt_b + act_b


class FinetuneEngine:
    """One frozen base continuously fine-tuned against by a churn of jobs.

    CONSTRUCTION (``core.engine_spec.EngineSpec``)::

        spec = EngineSpec(cfg=cfg, banks=(BankSpec("lora8", lora, 8),),
                          finetune=FinetuneConfig(max_jobs=8), mesh=None)
        engine = FinetuneEngine(spec, base_params)

    Each ``BankSpec`` pre-reserves its capacity for jobs matching its
    AdapterConfig (the stacked state comes up at the declared size instead
    of doubling under churn — same bucketed step programs, fewer
    reallocations). ``spec.mesh`` shards the engine: the frozen base by
    ``launch.shardings.base_param_specs`` (or replicated with
    ``spec.replicate_base``), bank params + optimizer state with their
    bank-slot axis over the batch axes; ``mesh=None`` is byte-identical to
    the single-device engine.

    FAULT CONTAINMENT (docs/robustness.md): per-job health records with
    tick-count backoff, per-row finite probes fused into the compact step
    (poisoned commits dropped in-scatter), quarantine-with-checkpoint,
    transactional admission, ``finished_early`` stream exhaustion, and
    whole-engine ``engine_state()`` / ``load_engine_state()`` crash
    recovery — survivors stay bitwise identical to a never-faulted run.

    DEPRECATED: the positional form ``FinetuneEngine(cfg, base_params,
    fcfg=..., router=...)`` still works but emits a ``DeprecationWarning``
    — migrate to the EngineSpec form (see docs/sharding.md)."""

    def __init__(self, spec, base_params, *,
                 fcfg: Optional[FinetuneConfig] = None, router=None,
                 health_policy: Optional[HealthPolicy] = None,
                 quarantine_dir: Optional[str] = None, debug: bool = False,
                 fault_hook=None, obs=None):
        if isinstance(spec, EngineSpec):
            if fcfg is not None:
                raise TypeError("pass the FinetuneConfig as EngineSpec."
                                "finetune, not fcfg=")
            self._setup(spec.cfg, base_params, fcfg=spec.finetune,
                        router=router, mesh=spec.mesh,
                        replicate_base=spec.replicate_base,
                        reserve={b.acfg: b.capacity for b in spec.banks},
                        spec=spec, health_policy=health_policy,
                        quarantine_dir=quarantine_dir, debug=debug,
                        fault_hook=fault_hook, obs=obs)
        else:
            warnings.warn(
                "FinetuneEngine(cfg, base_params) is deprecated; construct "
                "an EngineSpec and call FinetuneEngine(spec, base_params) "
                "(docs/sharding.md)", DeprecationWarning, stacklevel=2)
            self._setup(spec, base_params, fcfg=fcfg, router=router,
                        health_policy=health_policy,
                        quarantine_dir=quarantine_dir, debug=debug,
                        fault_hook=fault_hook, obs=obs)

    def _setup(self, cfg: ModelConfig, base_params, *,
               fcfg: Optional[FinetuneConfig] = None, router=None,
               mesh=None, replicate_base: bool = False,
               reserve: Optional[Dict[AdapterConfig, int]] = None,
               spec: Optional[EngineSpec] = None,
               health_policy: Optional[HealthPolicy] = None,
               quarantine_dir: Optional[str] = None, debug: bool = False,
               fault_hook=None, obs=None):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self._replicate_base = replicate_base
        self._reserve = reserve or {}
        if mesh is not None:
            from repro.launch import shardings
            # idempotent + identity-preserving (see ServingEngine._setup):
            # a base already placed by SymbiosisEngine.from_spec passes
            # through untouched, keeping the shared-base identity check
            base_params = shardings.shard_base_params(
                cfg, mesh, base_params, replicate=replicate_base)
        self.base = base_params
        self.fcfg = fcfg or FinetuneConfig()
        self.router = router
        self._queue: List[FinetuneJob] = []
        self._banks: Dict[BankKey, _Bank] = {}
        self._slot_of: Dict[int, tuple] = {}      # id(job) -> (BankKey, slot)
        self._step_of: Dict[int, int] = {}        # id(job) -> next global step
        self._placement: Dict[int, object] = {}
        self.finished: List[FinetuneJob] = []
        # fault containment (docs/robustness.md): per-job health records
        # live on the jobs themselves; quarantined jobs checkpoint to
        # quarantine_dir (when set) before retiring; debug runs the
        # conservation audit after every tick; fault_hook is the injection
        # point for the chaos sweep (called per admission attempt)
        self.health_policy = health_policy or HealthPolicy()
        self.quarantine_dir = quarantine_dir
        self.debug = debug
        self.fault_hook = fault_hook
        self._admission_faulted = False
        self.stats = {"train_ticks": 0, "train_steps": 0, "admitted": 0,
                      "retired": 0, "peak_jobs": 0, "compact_rows": 0,
                      "compact_padded": 0, "train_tokens": 0,
                      "faults": 0, "quarantined": 0, "finished_early": 0,
                      "dropped_steps": 0}
        # telemetry (docs/observability.md): obs=None keeps the tick loop
        # free of any timing machinery — _span is a shared nullcontext
        self._obs = obs
        self._span = _null_span if obs is None else obs.span
        if obs is not None:
            obs.attach("finetune", self)

    # ------------------------------------------------------------------
    def submit(self, job: FinetuneJob):
        if (job.init_adapter is None) != (job.init_opt is None):
            raise ValueError("resume needs both init_adapter and init_opt")
        if job.start_step >= job.steps:
            raise ValueError(f"start_step {job.start_step} >= step budget "
                             f"{job.steps}: nothing to run")
        nmb = job.microbatch
        if nmb and nmb > 1 and (job.batch_size % nmb or job.batch_size == nmb):
            # make_row_grad_fn would silently fall back to one full-batch
            # grad — the tenant asked for accumulation to SHRINK activation
            # memory, so refuse loudly instead of undercharging admission
            raise ValueError(
                f"microbatch {nmb} must strictly divide batch_size "
                f"{job.batch_size} (a non-dividing or degenerate factor "
                f"runs full-batch and holds full-batch activations)")
        self._queue.append(job)

    def pending(self) -> bool:
        return bool(self._queue or self._slot_of)

    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _bank_key(self, job: FinetuneJob) -> BankKey:
        return BankKey(job.acfg, job.batch_size, job.seq_len,
                       max(1, job.microbatch))

    def _try_admit(self, job: FinetuneJob) -> bool:
        if self.n_active >= self.fcfg.max_jobs:
            return False
        placement = None
        if self.router is not None:
            try:
                placement = self.router.route_train(
                    job_hbm_bytes(self.cfg, job, remat=self.fcfg.remat),
                    latency_sensitive=job.latency_sensitive)
            except RuntimeError:
                return False                      # queued until capacity frees
        # TRANSACTIONAL from here: the router charge is the only committed
        # state until the final bookkeeping block, and any failure below
        # must release it (satellite: a mid-admission exception used to
        # strand the charge forever)
        try:
            if self.fault_hook is not None:
                self.fault_hook("train_admit", id(job))
            if job.init_adapter is not None:
                adapter, opt = job.init_adapter, job.init_opt
            else:
                adapter = adapters_lib.init_adapter(
                    self.cfg, job.acfg, jax.random.PRNGKey(job.seed))
                opt = adamw_init(adapter)
            key = self._bank_key(job)
            bank = self._banks.setdefault(
                key, _Bank(key, reserve=self._reserve.get(job.acfg, 0)))
            slot = bank.alloc(adapter, opt)
        except BaseException as e:
            if placement is not None:
                self.router.release(placement)
            if isinstance(e, TransientFault):
                # injected/transient allocation failure: rolled back, job
                # stays queued and retries after backoff
                self._admission_faulted = True
                self.stats["faults"] += 1
                rec = job.health or HealthRecord()
                job.health = rec
                rec.trip(self.stats["train_ticks"],
                         f"admission: {e}", self.health_policy)
                if self._obs is not None:
                    self._obs.event("backoff", engine="finetune",
                                    tick=self.stats["train_ticks"],
                                    tenant=job.name,
                                    reason=f"admission: {e}",
                                    until=rec.next_eligible_tick)
                return False
            raise                                 # rolled back, not swallowed
        bank.slots[slot] = job
        self._place_bank(bank)
        self._slot_of[id(job)] = (key, slot)
        self._step_of[id(job)] = job.start_step
        self._placement[id(job)] = placement
        job.status = "active"
        self.stats["admitted"] += 1
        self.stats["peak_jobs"] = max(self.stats["peak_jobs"], self.n_active)
        if self._obs is not None:
            tick = self.stats["train_ticks"]
            self._obs.event("admit", engine="finetune", tick=tick,
                            tenant=job.name, bank=repr(key.acfg.method),
                            steps=job.steps - job.start_step)
            if job.health is not None and job.health.total_faults:
                self._obs.event("retry", engine="finetune", tick=tick,
                                tenant=job.name,
                                attempts=job.health.total_faults)
            if self.router is not None:
                u = self.router.utilization()
                self._obs.metrics.gauge("router_placements").set(
                    u["placements"])
                self._obs.metrics.gauge("router_committed_bytes").set(
                    u["committed_bytes"])
        return True

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def _mesh_ctx(self):
        """Ambient-mesh context for jitted dispatch (no-op single-device):
        binds the engine mesh while tracing/running a step so the soft
        constraints inside the hot path (``common.constrain``) resolve."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.launch.mesh import mesh_context
        return mesh_context(self.mesh)

    def _place_bank(self, bank: _Bank):
        """``device_put`` a bank's stacked params/opt onto the mesh (slot
        axis over the batch axes). Idempotent — re-run after every alloc so
        growth reallocations land back on their specs."""
        if self.mesh is None:
            return
        from repro.launch import shardings
        bank.params = shardings.put_tree(
            self.mesh, bank.params,
            shardings.bank_state_specs(self.cfg, self.mesh, bank.params))
        bank.opt = shardings.put_tree(
            self.mesh, bank.opt,
            shardings.bank_state_specs(self.cfg, self.mesh, bank.opt))

    def _row_bucket(self, n: int, cap: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return min(b, cap) if cap else b

    def _bank_tick(self, bank: _Bank):
        tick = self.stats["train_ticks"]
        # gather this tick's runnable rows: skip tenants backing off, and
        # contain per-job data-stream failures HERE — one tenant's stream
        # must never unwind the other tenants' tick
        rows = []
        for s, job in enumerate(bank.slots):
            if job is None:
                continue
            if job.health is not None and not job.health.eligible(tick):
                continue                           # SUSPECT: backoff gate
            try:
                b = job.data.batch(self._step_of[id(job)])
            except StreamExhausted as e:
                self._finish_early(job, str(e))
                continue
            except Exception as e:                 # noqa: BLE001 — classified
                self._job_fault(job, tick, e)
                continue
            rows.append((s, job, b))
        if not rows:
            return
        with self._span("compact_gather"):
            R = self._row_bucket(len(rows), bank.cap)
            slots = np.zeros((R,), np.int32)
            mask = np.zeros((R,), bool)
            hyper = {k: np.zeros((R,), np.float32)
                     for k in ("lr", "warmup", "total", "wd", "gnorm")}
            hyper["step"] = np.zeros((R,), np.int32)
            batches = []
            for i, (s, job, b) in enumerate(rows):
                slots[i], mask[i] = s, True
                step = self._step_of[id(job)]
                hyper["step"][i] = step
                hyper["lr"][i] = job.lr
                hyper["warmup"][i] = job.warmup_steps
                hyper["total"][i] = job.schedule_total
                hyper["wd"][i] = job.weight_decay
                hyper["gnorm"][i] = job.max_grad_norm if job.max_grad_norm > 0 \
                    else np.inf
                batches.append(b)
            n = len(batches)

            def stack(*leaves):
                pads = [jnp.zeros_like(leaves[0])] * (R - n)
                return jnp.stack(list(leaves) + pads)

            batch = jax.tree.map(stack, *batches)
        step_fn = _jit_compact_train(self.cfg, bank.key.acfg,
                                     bank.key.microbatch,
                                     self.fcfg.memory_optimized,
                                     self.fcfg.remat, self.mesh)
        with self._span("train_step"), self._mesh_ctx():
            bank.params, bank.opt, metrics = tracecount.dispatch(
                self, "compact_train", (bank.key, R), step_fn,
                self.base, bank.params, bank.opt, batch, jnp.asarray(slots),
                jnp.asarray(mask),
                {k: jnp.asarray(v) for k, v in hyper.items()})
        with self._span("device_sync"):
            losses = np.asarray(metrics["loss"])
            finite = np.asarray(metrics["finite"])
        obs = self._obs
        committed = 0
        with self._span("scatter"):
            for i, (_, job, _b) in enumerate(rows):
                if finite[i]:
                    job.losses.append(float(losses[i]))
                    self._step_of[id(job)] += 1
                    if job.health is not None:
                        job.health.ok(tick)
                    committed += 1
                    if obs is not None:
                        label = job.name or "anon"
                        obs.metrics.counter(
                            "train_steps_total", job=label).inc()
                        obs.metrics.counter(
                            "train_tokens_total", job=label).inc(
                                bank.key.batch * bank.key.seq)
                        obs.metrics.gauge("train_loss", job=label).set(
                            float(losses[i]))
                else:
                    # the in-step probe tripped: the jitted scatter already
                    # dropped this row's commit (its slot kept the last clean
                    # params/opt state), so quarantine checkpoints CLEAN state
                    self.stats["dropped_steps"] += 1
                    self._job_fault(job, tick, NonFiniteFault(
                        f"non-finite loss/grads at step "
                        f"{self._step_of[id(job)]}"))
        self.stats["train_steps"] += committed
        self.stats["compact_rows"] += n
        self.stats["compact_padded"] += R - n
        self.stats["train_tokens"] += committed * bank.key.batch * bank.key.seq

    # ------------------------------------------------------------------
    # fault containment (docs/robustness.md)
    # ------------------------------------------------------------------
    def _job_fault(self, job: FinetuneJob, tick: int, exc: BaseException):
        """Classify one job's fault: transient -> SUSPECT with deterministic
        tick-count backoff (state untouched, retried from the last clean
        step); fatal or retries exhausted -> quarantine."""
        self.stats["faults"] += 1
        rec = job.health or HealthRecord()
        job.health = rec
        reason = f"{type(exc).__name__}: {exc}"
        if classify(exc) == "transient":
            if rec.trip(tick, reason, self.health_policy) == "retry":
                if self._obs is not None:
                    self._obs.event("backoff", engine="finetune", tick=tick,
                                    tenant=job.name, reason=reason,
                                    until=rec.next_eligible_tick)
                return
        else:
            rec.quarantine(tick, reason)
        self._quarantine_job(job)

    def _quarantine_job(self, job: FinetuneJob):
        """Fatal path: checkpoint the job's last CLEAN state (best effort —
        a failing checkpoint write must not block retirement), then retire
        it, releasing its bank slot and router charge."""
        if self.quarantine_dir is not None:
            try:
                self.checkpoint_job(job, self.quarantine_dir)
            except Exception as e:                 # noqa: BLE001
                if job.health is not None:
                    job.health.history.append(
                        (self.stats["train_ticks"], "quarantined",
                         f"quarantine checkpoint failed: {e}"))
        self.stats["quarantined"] += 1
        if self._obs is not None:
            last = job.health.last_transition() if job.health else None
            self._obs.event("quarantine", engine="finetune",
                            tick=self.stats["train_ticks"], tenant=job.name,
                            scope="job",
                            reason=last[2] if last else "quarantined")
        self.retire(job, status="quarantined")

    def _finish_early(self, job: FinetuneJob, reason: str):
        """Stream ran dry inside the step budget: complete the job as
        ``finished_early`` — checkpointed (when a quarantine_dir is set),
        charges released, result handed back — instead of raising out of
        train_tick."""
        if self.quarantine_dir is not None:
            try:
                self.checkpoint_job(job, self.quarantine_dir)
            except Exception:                      # noqa: BLE001 — best effort
                pass
        if job.health is not None:
            job.health.retire(self.stats["train_ticks"], reason)
        self.stats["finished_early"] += 1
        self.retire(job, status="finished_early")

    def trace_domain(self) -> tracecount.TraceDomain:
        """Legal jit keys (analysis 'buckets' pass): one compile per
        (bank key, row bucket) with the bucket a power of two — capacity
        doubles, membership gathers into power-of-two buckets, so any other
        row count compiling is a hot-path recompile."""
        d = tracecount.TraceDomain()
        d.declare("compact_train",
                  predicate=lambda key: (isinstance(key, tuple) and
                                         len(key) == 2 and key[1] >= 1 and
                                         key[1] & (key[1] - 1) == 0))
        return d

    def train_tick(self) -> bool:
        """Admit due jobs, run one optimizer step for every active job
        (one compact call per non-empty bank), retire exhausted jobs.
        Returns True while jobs remain active or queued. Per-job faults are
        contained (health machine + quarantine, docs/robustness.md) — one
        tenant's stream/NaN/allocation failure never unwinds the tick."""
        tick = self.stats["train_ticks"]
        obs = self._obs
        t0 = obs.tick_start("finetune") if obs is not None else 0.0
        self._admission_faulted = False
        admitted_any = False
        backing_off = 0
        with self._span("admit"):
            for job in list(self._queue):
                if job.health is not None and not job.health.active:
                    # admission retries exhausted: reject without crashing
                    self._queue.remove(job)
                    job.status = "quarantined"
                    self.stats["quarantined"] += 1
                    self.finished.append(job)
                    if obs is not None:
                        obs.event("quarantine", engine="finetune", tick=tick,
                                  tenant=job.name, scope="job",
                                  reason="admission retries exhausted")
                    continue
                if job.health is not None and not job.health.eligible(tick):
                    backing_off += 1
                    continue                       # SUSPECT: retry later
                if self._try_admit(job):
                    self._queue.remove(job)
                    admitted_any = True
        if obs is not None and backing_off:
            obs.metrics.counter("train_backoff_skips_total").inc(backing_off)
        if self._queue and not self._slot_of and not admitted_any \
                and not self._admission_faulted and not backing_off:
            raise RuntimeError(
                f"{len(self._queue)} job(s) can never be admitted "
                f"(no free capacity and nothing running)")
        for bank in self._banks.values():
            self._bank_tick(bank)
        self.stats["train_ticks"] += 1
        for job in [j for (key, s) in list(self._slot_of.values())
                    for j in [self._banks[key].slots[s]]
                    if self._step_of[id(j)] >= j.steps]:
            self.retire(job)
        if self.debug:
            from repro.faults.audit import finetune_conservation
            errs = finetune_conservation(self)
            if errs:
                raise AssertionError("conservation audit failed after "
                                     f"train tick {tick}:\n  "
                                     + "\n  ".join(errs))
        if obs is not None:
            obs.tick_end("finetune", tick, t0)
        return self.pending()

    def drain_events(self, *, client=None, kind=None) -> list:
        """Client-visible event feed (docs/observability.md): drain this
        engine's structured events — admit/retire/backoff/retry/quarantine/
        compile — optionally filtered to one tenant (``client`` matches the
        job's ``name``) or one ``kind``. Returns [] when no telemetry is
        attached; draining is destructive for the matched events only."""
        if self._obs is None:
            return []
        if client is None:
            return self._obs.drain_events(kind=kind, engine="finetune")
        return self._obs.drain_events(client=client, kind=kind,
                                      engine="finetune")

    def run(self) -> List[FinetuneJob]:
        """Drive all queued/active jobs to their step budgets."""
        while self.train_tick():
            pass
        out, self.finished = self.finished, []
        return out

    # ------------------------------------------------------------------
    # job state, retirement, checkpointing
    # ------------------------------------------------------------------
    def job_state(self, job: FinetuneJob):
        """(adapter, opt, next_step) for an ACTIVE job — a device-side
        snapshot of its bank slot (used for checkpoints and inspection)."""
        key, slot = self._slot_of[id(job)]
        adapter, opt = self._banks[key].read(slot)
        return adapter, opt, self._step_of[id(job)]

    def retire(self, job: FinetuneJob, *, status: str = "finished") -> JobResult:
        """Remove a job from service (explicit mid-run leave, budget
        exhaustion, ``finished_early`` stream end, or quarantine) and hand
        back its state. The bank slot frees for the next admission; the
        stale row is never read again; the router charge releases."""
        adapter, opt, step = self.job_state(job)
        key, slot = self._slot_of.pop(id(job))
        self._banks[key].slots[slot] = None
        del self._step_of[id(job)]
        placement = self._placement.pop(id(job), None)
        if placement is not None:
            self.router.release(placement)
        job.status = status
        if job.health is not None and status != "quarantined":
            job.health.retire(self.stats["train_ticks"], status)
        job.result = JobResult(adapter=adapter, opt=opt, step=step,
                               losses=list(job.losses))
        self.finished.append(job)
        self.stats["retired"] += 1
        if self._obs is not None:
            self._obs.event("retire", engine="finetune",
                            tick=self.stats["train_ticks"], tenant=job.name,
                            status=status, steps=step)
            if self.router is not None:
                u = self.router.utilization()
                self._obs.metrics.gauge("router_placements").set(
                    u["placements"])
                self._obs.metrics.gauge("router_committed_bytes").set(
                    u["committed_bytes"])
        return job.result

    def checkpoint_job(self, job: FinetuneJob, directory: str) -> str:
        """Write an ACTIVE job's adapter + optimizer state (resume with
        ``checkpoint.restore_job_state`` + ``FinetuneJob(init_adapter=...,
        init_opt=..., start_step=...)``)."""
        from repro.checkpoint import save_job_state
        adapter, opt, step = self.job_state(job)
        return save_job_state(directory, step, adapter, opt,
                              name=job.name or "job")

    # ------------------------------------------------------------------
    # whole-engine crash recovery (docs/robustness.md)
    # ------------------------------------------------------------------
    def _job_fields(self, job: FinetuneJob) -> dict:
        return dict(acfg=job.acfg, data=job.data, batch_size=job.batch_size,
                    seq_len=job.seq_len, steps=job.steps, lr=job.lr,
                    weight_decay=job.weight_decay,
                    warmup_steps=job.warmup_steps,
                    total_steps=job.total_steps,
                    max_grad_norm=job.max_grad_norm,
                    microbatch=job.microbatch, name=job.name, seed=job.seed,
                    latency_sensitive=job.latency_sensitive)

    def engine_state(self) -> dict:
        """A picklable snapshot of every tenant: active jobs carry their
        device-side adapter/optimizer state (as numpy), their global step,
        loss history, health record and data-stream object (streams pickle
        with their cursor — see ``faults.FaultyStream``); queued and
        finished jobs ride along. Feed to ``checkpoint.save_engine_state``;
        restore into a FRESH engine (same spec + base) with
        ``load_engine_state`` — every job resumes its uninterrupted
        trajectory bitwise (the step counter drives both the schedule and
        the deterministic stream). Single-device engines only."""
        if self.mesh is not None:
            raise NotImplementedError(
                "whole-engine checkpointing is single-device (mesh=None)")
        tonp = lambda t: (None if t is None else
                          jax.tree.map(np.asarray, jax.device_get(t)))
        active = []
        for jid, (key, slot) in self._slot_of.items():
            job = self._banks[key].slots[slot]
            adapter, opt, step = self.job_state(job)
            active.append(dict(self._job_fields(job),
                               init_adapter=tonp(adapter), init_opt=tonp(opt),
                               start_step=step, losses=list(job.losses),
                               status=job.status, health=job.health))
        def _rec(job):
            return dict(self._job_fields(job),
                        init_adapter=tonp(job.init_adapter),
                        init_opt=tonp(job.init_opt),
                        start_step=job.start_step, losses=list(job.losses),
                        status=job.status, health=job.health,
                        result=None if job.result is None else dict(
                            adapter=tonp(job.result.adapter),
                            opt=tonp(job.result.opt), step=job.result.step,
                            losses=list(job.result.losses)))
        return {"active": active,
                "queued": [_rec(j) for j in self._queue],
                "finished": [_rec(j) for j in self.finished],
                "stats": dict(self.stats)}

    def load_engine_state(self, state: dict):
        """Restore an ``engine_state()`` snapshot into this freshly
        constructed engine. Active jobs re-enter the queue (in their
        original admission order) as resume jobs — the next ``train_tick``
        re-routes their charges and re-allocates bank slots; slot indices
        may differ but the math is slot-invariant, so each tenant's
        trajectory continues bit-for-bit."""
        if self._slot_of or self._queue or self.finished:
            raise RuntimeError("load_engine_state needs a freshly "
                               "constructed engine (no jobs)")
        def _job(rec):
            r = dict(rec)
            result = r.pop("result", None)
            losses = r.pop("losses", [])
            status = r.pop("status", "queued")
            job = FinetuneJob(**{k: v for k, v in r.items() if k != "health"})
            job.losses = list(losses)
            job.status = "queued" if status == "active" else status
            job.health = rec.get("health")
            if result is not None:
                job.result = JobResult(**result)
            return job
        for rec in state["active"]:
            self._queue.append(_job(rec))
        for rec in state["queued"]:
            self._queue.append(_job(rec))
        for rec in state["finished"]:
            self.finished.append(_job(rec))
        self.stats.update(state["stats"])
