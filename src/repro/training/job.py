"""Fine-tuning jobs: the unit of admission of fine-tuning as a service.

A ``FinetuneJob`` is one tenant's fine-tuning request: its own PEFT
selection (``AdapterConfig`` — method, rank, targets), its own optimizer
hyperparameters and warmup-cosine schedule, its own data stream and
grad-accum microbatching, and a step budget after which the engine retires
it and hands back its final state. Jobs join and leave the service
independently (paper §3, §5: 20 adapters fine-tuned simultaneously against
one shared frozen base, each free to pick its own configuration).

Resumption: a retired job's ``JobResult`` (or a checkpoint written with
``checkpoint.save_job_state``) can seed a NEW job via ``init_adapter`` /
``init_opt`` / ``start_step`` — the re-admitted job continues the same
optimizer trajectory bit-for-bit (its schedule position and data stream
both key off the global step count).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

from repro.config import AdapterConfig, ModelConfig
from repro.data import make_client_batches


@dataclasses.dataclass(eq=False)        # identity eq: engines key on id(job)
class FinetuneJob:
    """One fine-tuning tenant. ``data.batch(step) -> {tokens [B, S], labels
    [B, S], ...}`` must be deterministic in ``step`` for checkpoint-resume
    to reproduce the original trajectory."""
    acfg: AdapterConfig
    data: Any                             # per-step batch stream (see above)
    batch_size: int
    seq_len: int
    steps: int = 10                       # optimizer-step budget (global count)
    lr: float = 1e-3
    weight_decay: float = 0.0
    warmup_steps: int = 0
    total_steps: int = 0                  # schedule horizon; 0 -> ``steps``
    max_grad_norm: float = 1.0            # 0 -> no clipping
    microbatch: int = 0                   # grad-accum factor (0/1 -> off)
    name: str = ""
    seed: int = 0                         # adapter init key (fresh jobs)
    latency_sensitive: bool = False
    # --- resumption (all three or none) ---
    init_adapter: Any = None
    init_opt: Any = None
    start_step: int = 0
    # --- engine-filled ---
    losses: List[float] = dataclasses.field(default_factory=list)
    result: Optional["JobResult"] = None
    # lifecycle: queued | active | finished | finished_early | quarantined
    # (docs/robustness.md — finished_early = stream ran dry inside the step
    # budget; quarantined = fatal fault, state checkpointed then retired)
    status: str = "queued"
    health: Optional[Any] = None          # faults.HealthRecord, engine-filled

    @property
    def schedule_total(self) -> int:
        return self.total_steps or self.steps

    @property
    def fault_history(self) -> List[tuple]:
        """Client-visible fault trajectory (docs/observability.md): the
        health record's ``(tick, state, reason)`` entries, or [] for a job
        that never faulted — the training twin of
        ``serving.Request.fault_history``."""
        return [] if self.health is None else list(self.health.history)


@dataclasses.dataclass
class JobResult:
    """A retired job's client-side state, as handed back by the service."""
    adapter: Any
    opt: Any
    step: int                             # optimizer steps completed (global)
    losses: List[float]


class _ClientSliceStream:
    """One client slice of a multi-client batch stream, leaves [B, ...].
    Module-level (not a closure) so job streams pickle into the
    whole-engine checkpoint (``checkpoint.save_engine_state``)."""

    def __init__(self, stream):
        self._stream = stream

    def batch(self, step):
        import jax
        return jax.tree.map(lambda x: x[0], self._stream.batch(step))


def make_job_stream(cfg: ModelConfig, batch: int, seq_len: int, *,
                    seed: int = 0):
    """Deterministic per-job data stream: one client slice of the synthetic
    Markov pipeline (plus the family's frontend extras), leaves [B, ...]."""
    return _ClientSliceStream(make_client_batches(cfg, 1, batch, seq_len,
                                                  seed=seed))
