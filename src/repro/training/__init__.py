from repro.core.engine_spec import BankSpec, EngineSpec
from repro.training.job import FinetuneJob, JobResult, make_job_stream
from repro.training.engine import FinetuneEngine, BankKey, job_hbm_bytes
from repro.training.service import SymbiosisEngine
