"""SymbiosisEngine: inference and fine-tuning time-sharing ONE frozen base.

The paper's full service shape (§4.4): a provider keeps a single resident
copy of the base params and multiplexes it between a ``ServingEngine``
(continuous-batching decode over adapter clients) and a ``FinetuneEngine``
(fine-tuning as a service over PEFT jobs) — instead of deploying one model
replica per workload. This wrapper interleaves the two engines' ticks;
because the base is frozen and each engine owns its client-side state,
interleaving changes WHEN work runs, never its math: serving outputs and
every job's training trajectory are bit-for-bit identical to running each
engine alone (asserted in tests/test_finetune_engine.py and the tier2
mixed-workload sweep).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.engine_spec import EngineSpec
from repro.serving.engine import Request, ServingEngine
from repro.training.engine import FinetuneEngine
from repro.training.job import FinetuneJob


class SymbiosisEngine:
    """Tick-interleaves a serving engine and a fine-tuning engine that close
    over the SAME base-parameter tree (checked leaf-by-leaf at
    construction — a copy would silently double the base HBM and break the
    whole point)."""

    def __init__(self, serving: Optional[ServingEngine] = None,
                 finetune: Optional[FinetuneEngine] = None, *,
                 train_every: int = 1):
        if serving is None and finetune is None:
            raise ValueError("need at least one of serving / finetune")
        if serving is not None and finetune is not None:
            s_leaves = jax.tree.leaves(serving.base)
            f_leaves = jax.tree.leaves(finetune.base)
            if len(s_leaves) != len(f_leaves) or any(
                    a is not b for a, b in zip(s_leaves, f_leaves)):
                raise ValueError(
                    "serving and finetune engines must share ONE frozen "
                    "base (identical param arrays, not copies)")
        self.serving = serving
        self.finetune = finetune
        self.train_every = max(1, train_every)
        self.stats = {"ticks": 0, "decode_ticks": 0, "train_ticks": 0,
                      "admission_stalls": 0}

    @classmethod
    def from_spec(cls, spec: EngineSpec, base_params, *,
                  serving_banks=None, router=None, train_every: int = 1,
                  policy: Optional[str] = None, obs=None, **serving_kw):
        """Build the full symbiotic service from ONE ``EngineSpec``: a
        ``ServingEngine`` when ``spec.serve`` is set (over ``serving_banks``
        — one client-stacked adapter tree per ``spec.banks`` entry), a
        ``FinetuneEngine`` when ``spec.finetune`` is set, both closing over
        the SAME base tree. Under ``spec.mesh`` the base is sharded ONCE
        here; the engines' own placement is idempotent and identity-
        preserving, so the shared-base leaf check still holds. One ``obs``
        (docs/observability.md) is shared by both engines — their spans,
        metrics and events land in a single registry/event log, labelled
        ``serving`` / ``finetune``."""
        if spec.mesh is not None:
            from repro.launch import shardings
            base_params = shardings.shard_base_params(
                spec.cfg, spec.mesh, base_params,
                replicate=spec.replicate_base)
        serving = None
        if spec.serve is not None:
            if serving_banks is None:
                raise ValueError("spec.serve is set: pass serving_banks= "
                                 "(one adapter tree per spec bank)")
            serving = ServingEngine(spec, base_params, serving_banks,
                                    router=router, policy=policy, obs=obs,
                                    **serving_kw)
        finetune = None
        if spec.finetune is not None:
            finetune = FinetuneEngine(spec, base_params, router=router,
                                      obs=obs)
        return cls(serving=serving, finetune=finetune,
                   train_every=train_every)

    # ------------------------------------------------------------------
    def submit(self, item):
        """Route a ``Request`` to serving, a ``FinetuneJob`` to training."""
        if isinstance(item, Request):
            if self.serving is None:
                raise ValueError("no serving engine attached")
            self.serving.submit(item)
        elif isinstance(item, FinetuneJob):
            if self.finetune is None:
                raise ValueError("no finetune engine attached")
            self.finetune.submit(item)
        else:
            raise TypeError(f"cannot route {type(item).__name__}")

    def tick(self) -> bool:
        """One service tick: a decode tick (if serving work exists) then
        ``train_every`` train ticks (if jobs exist). Returns True while
        either engine still has work.

        Each engine's standalone stuck detection ("can never be admitted")
        assumes nothing outside itself will ever free capacity. Under a
        SHARED PlacementRouter that assumption is wrong in exactly this
        configuration — a queued request may be waiting on HBM pinned by a
        fine-tuning job (or vice versa) — so a stall in one engine is
        fatal only when the OTHER engine holds nothing that could free."""
        did = False
        if self.serving is not None and self.serving.pending():
            try:
                self.serving.service_tick()
                self.stats["decode_ticks"] += 1
                did = True
            except RuntimeError:
                if not (self.finetune is not None and self.finetune.n_active):
                    raise          # nothing training-side will ever free
                self.stats["admission_stalls"] += 1
        for _ in range(self.train_every):
            if self.finetune is not None and self.finetune.pending():
                try:
                    self.finetune.train_tick()
                    self.stats["train_ticks"] += 1
                    did = True
                except RuntimeError:
                    if not (self.serving is not None
                            and self.serving.n_inflight):
                        raise      # nothing serving-side will ever free
                    self.stats["admission_stalls"] += 1
        if did:
            self.stats["ticks"] += 1
        return did

    def drain_events(self, *, client=None, kind=None) -> list:
        """Merged client-visible event feed (docs/observability.md): drain
        both engines' structured events, ordered by global sequence number.
        When the engines share one ``Obs`` (the ``from_spec`` path) the
        underlying log is drained once; distinct obs objects are each
        drained and the results merged."""
        seen, out = set(), []
        for eng in (self.serving, self.finetune):
            obs = getattr(eng, "_obs", None)
            if eng is None or obs is None or id(obs) in seen:
                continue
            seen.add(id(obs))
            if client is None:
                out.extend(obs.drain_events(kind=kind))
            else:
                out.extend(obs.drain_events(client=client, kind=kind))
        out.sort(key=lambda e: e.seq)
        return out

    def run(self):
        """Drive both workloads to completion against the shared base.
        Returns (finished inference Requests, finished FinetuneJobs)."""
        while self.tick():
            pass
        done_reqs = self.serving.drain_done() if self.serving else []
        done_jobs = []
        if self.finetune is not None:
            done_jobs, self.finetune.finished = self.finetune.finished, []
        return done_reqs, done_jobs

    # ------------------------------------------------------------------
    # engine-level crash recovery (docs/robustness.md)
    # ------------------------------------------------------------------
    def checkpoint(self, directory) -> int:
        """Atomically write BOTH engines' whole-engine snapshots plus the
        wrapper's own stats as one CRC-framed blob
        (``checkpoint.save_engine_state``); returns the sequence number.
        Kill → ``restore`` into freshly constructed engines resumes every
        tenant bitwise (tests/test_faults.py)."""
        import re
        from repro.checkpoint import save_engine_state
        state = {
            "serving": (None if self.serving is None
                        else self.serving.engine_state()),
            "finetune": (None if self.finetune is None
                         else self.finetune.engine_state()),
            "stats": dict(self.stats),
        }
        path = save_engine_state(directory, state)
        return int(re.search(r"engine_(\d+)\.ckpt$", path).group(1))

    def restore(self, directory) -> int:
        """Load the newest VALID engine snapshot (corrupt files are skipped
        — last-good-wins) into this freshly constructed service; returns
        the sequence number restored."""
        from repro.checkpoint import load_engine_state
        seq, state = load_engine_state(directory)
        if state["serving"] is not None:
            if self.serving is None:
                raise RuntimeError("checkpoint holds serving state but no "
                                   "serving engine is attached")
            self.serving.load_engine_state(state["serving"])
        if state["finetune"] is not None:
            if self.finetune is None:
                raise RuntimeError("checkpoint holds finetune state but no "
                                   "finetune engine is attached")
            self.finetune.load_engine_state(state["finetune"])
        self.stats.update(state["stats"])
        return seq
