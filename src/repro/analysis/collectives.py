"""Collective audit: no collective may move a base-weight-sized tensor.

The precondition for the sharding tentpole (ROADMAP): once hot-path steps
compile under a real mesh, an accidental replication of the frozen base —
XLA inserting an ``all-gather`` whose destination is a full base weight —
would silently multiply the dominant HBM/ICI cost per step. This pass
compiles a step under a mesh spec, walks the partitioned HLO with
``launch.hlo_analysis.find_collectives`` (loop-aware, async pairs counted
once), and flags:

* **error** — a collective whose result (any tuple element) has exactly a
  base-leaf (dtype, dims) signature: the step gathers/reduces a full base
  weight;
* **warning** — a collective moving at least ``threshold_bytes`` (default:
  the largest base leaf) without an exact signature match: not provably
  the base, but base-scale traffic worth a look.

Expected, legal traffic — activation collectives, adapter-sized
reductions — passes untouched. ``allow_kinds`` downgrades exact-base hits
of those kinds to warnings: the FSDP executor mode deliberately
``all-gather``\\ s frozen weights per layer (see ``launch.shardings``), so
gather-type collectives at base shape are design, while a reduce-type
collective at base shape is always gradient sync of the frozen base — an
error no mode permits.
"""
from __future__ import annotations

from typing import Iterable

import jax
import numpy as np

from repro.analysis.report import ERROR, PassResult, WARNING
from repro.launch import hlo_analysis


def base_leaf_sigs(base_params) -> set:
    """(hlo dtype, dims) signatures of every frozen-base leaf."""
    from repro.analysis.aliasing import hlo_dtype
    return {(hlo_dtype(leaf.dtype), tuple(leaf.shape))
            for leaf in jax.tree.leaves(base_params)}


def audit_collectives(hlo_text: str, base_params, *, target: str,
                      threshold_bytes: int | None = None,
                      allow_kinds: Iterable[str] = (),
                      pass_name: str = "collectives") -> PassResult:
    """Audit one partitioned module's collectives against the base tree."""
    res = PassResult(pass_name, target)
    sigs = base_leaf_sigs(base_params)
    # From shape/dtype, not np.asarray: the dry-run passes ShapeDtypeStruct
    # stand-ins, which asarray would box into a 0-d object array (8 bytes)
    # and collapse the threshold to noise.
    leaf_bytes = [int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                  for leaf in jax.tree.leaves(base_params)]
    if threshold_bytes is None:
        threshold_bytes = max(leaf_bytes) if leaf_bytes else 1 << 30
    ops = hlo_analysis.find_collectives(hlo_text)
    res.checked["collectives"] = len(ops)
    res.checked["threshold_bytes"] = int(threshold_bytes)
    for op in ops:
        hit = [s for s in op.shapes if s in sigs]
        if hit:
            dt, dims = hit[0]
            allowed = op.kind in allow_kinds
            res.add(
                f"{op.kind} (x{op.mult} in {op.computation}) moves a tensor "
                f"of exact base-weight shape {dt}{list(dims)} — "
                + ("a per-layer frozen-weight gather (allowed FSDP mode, "
                   "flagged for visibility)" if allowed else
                   "the step gathers or reduces a full frozen-base leaf "
                   "per execution"),
                WARNING if allowed else ERROR,
                kind=op.kind, dtype=dt, dims=list(dims), mult=op.mult,
                hlo=op.line[:200],
            )
        elif op.bytes >= threshold_bytes:
            res.add(
                f"{op.kind} (x{op.mult} in {op.computation}) moves "
                f"{op.bytes} bytes >= largest base leaf "
                f"({threshold_bytes}B) without matching a base shape — "
                "base-scale collective traffic",
                WARNING, kind=op.kind, bytes=int(op.bytes), mult=op.mult,
                hlo=op.line[:200],
            )
    return res
