"""Run the analysis passes over the standard hot-path targets.

``run_static`` covers the per-target jaxpr/HLO passes (donation, poolcopy,
MoE remat structure, frozen-base taint); ``run_isolation`` the runtime
differential probes; ``run_buckets`` drives a small real engine workload —
serving with staggered admissions plus dynamic bank admission, and a
multi-job fine-tuning churn — under the trace-count guard. The CLI
(``python -m repro.analysis``) additionally compiles targets under a
multi-device mesh for the collective audit (``run_collectives``).
"""
from __future__ import annotations

import numpy as np

from repro.analysis import aliasing, collectives, jaxpr_passes, taint, tracecount
from repro.analysis.report import PassResult
from repro.analysis.targets import StepTarget, all_targets, tiny_config
from repro.config import MOE, DENSE, AdapterConfig, ServeConfig, FinetuneConfig


def run_static(targets=None) -> list:
    results = []
    for t in targets if targets is not None else all_targets():
        hlo = aliasing.compile_text(t.fn, t.args, t.donate_argnums)
        results.append(aliasing.check_donation(
            hlo, t.donated, target=t.name, frozen_leaves=t.frozen))
        jx = None
        if t.protected_leaves:
            jx = t.jaxpr()
            results.append(jaxpr_passes.check_pool_copies(
                jx, t.protected_sigs, target=t.name))
        if t.arch == MOE and t.kind == "train":
            jx = jx if jx is not None else t.jaxpr()
            results.append(jaxpr_passes.check_moe_checkpointed(
                jx, target=t.name))
        if t.kind == "train":
            results.append(taint.check_frozen_base(
                t.fn, t.args, update_argnums=t.donate_argnums,
                target=t.name))
    return results


def run_isolation(targets=None) -> list:
    """Differential client/row isolation probes on the compact steps."""
    from repro.core import symbiosis
    import jax

    results = []
    for t in targets if targets is not None else all_targets():
        iso = t.isolation
        if not iso:
            continue
        if t.kind == "serving":
            scfg = iso["scfg"]
            cfg = tiny_config(t.arch)
            cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg)
            page_axes = symbiosis.cache_page_axes(cfg, scfg.max_seq, **cache_kw)
            client_axes = jax.tree.map(
                lambda pax: 0 if pax is None else None, page_axes,
                is_leaf=lambda x: x is None)
            base, bank, caches = t.args[0], t.args[1], t.args[2]
            extra = tuple(jax.numpy.asarray(e) for e in iso["extra"])
            n_blocks = -(-scfg.max_seq // scfg.page_block)
            fn = t.fn
            if iso.get("probe"):
                # health-probed steps return (logits, finite, caches); the
                # isolation checker's contract is (out, new_caches)
                fn = (lambda f: lambda *a: (lambda o: (o[0], o[-1]))(f(*a)))(
                    t.fn)
            results.append(taint.check_client_isolation(
                fn, base, bank, caches, extra,
                clients=np.asarray(iso["extra"][1]), victim=iso["victim"],
                pool_pages=2 * n_blocks,  # max_b * n_blocks per client
                page_axes=page_axes, slot_axes=client_axes,
                target=t.name))
        else:
            results.append(taint.check_row_isolation(
                t.fn, t.args, perturb_row=iso["perturb_row"],
                victim_slot=iso["victim_slot"],
                perturb_argnums=iso["perturb_argnums"], target=t.name))
    return results


def run_buckets() -> PassResult:
    """Real engine workloads under the trace-count guard: serving ticks
    with staggered admission and a live ``admit_bank`` growth, then a
    fine-tuning churn — every compile must land in the declared domains."""
    import jax
    from repro.core import symbiosis
    from repro.core.engine_spec import BankSpec, EngineSpec
    from repro.serving.engine import Request, ServingEngine
    from repro.training.engine import FinetuneEngine
    from repro.training.job import FinetuneJob, make_job_stream

    cfg = tiny_config(DENSE)
    lora = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
    with tracecount.guard("engine-workload") as g:
        scfg = ServeConfig(n_clients=2, max_seq=32, page_block=8)
        base, bank, _ = symbiosis.init_system(cfg, lora, 2,
                                              jax.random.PRNGKey(0))
        spec = EngineSpec(cfg=cfg,
                          banks=(BankSpec("tenants", lora, capacity=2),),
                          serve=scfg, max_batch_per_client=2)
        eng = ServingEngine(spec, base, [bank])
        rng = np.random.default_rng(0)
        for c in range(2):
            eng.submit(Request(client_id=c,
                               prompt=rng.integers(0, cfg.vocab, (1, 6))
                               .astype(np.int32),
                               max_new_tokens=3))
        eng.run()
        # live bank growth: new client ids, grown buckets, a new epoch
        extra = symbiosis.init_system(cfg, lora, 1, jax.random.PRNGKey(9))[1]
        adm = eng.admit_bank(lora, extra)
        eng.submit(Request(client_id=adm.client_ids[0],
                           prompt=rng.integers(0, cfg.vocab, (1, 6))
                           .astype(np.int32), max_new_tokens=3))
        eng.run()
        eng.retire_bank(adm)

        ft = FinetuneEngine(
            EngineSpec(cfg=cfg, finetune=FinetuneConfig(max_jobs=4)), base)
        for i in range(2):
            ft.submit(FinetuneJob(acfg=lora,
                                  data=make_job_stream(cfg, 2, 8, seed=i),
                                  batch_size=2, seq_len=8, steps=2))
        ft.run()
    return g.result()


def run_collectives(targets=None, *, mesh=None) -> list:
    """Compile each target under a mesh (or single-device) and audit the
    partitioned HLO for base-sized collectives. With a real multi-device
    mesh the base is sharded via ``launch.shardings.base_param_specs``;
    single-device compiles must trivially contain no collectives at all."""
    import jax

    results = []
    for t in targets if targets is not None else all_targets():
        if mesh is None:
            hlo = aliasing.compile_text(t.fn, t.args, t.donate_argnums)
        else:
            from repro.launch.shardings import base_param_specs

            base = t.args[t.base_argnum]
            specs = base_param_specs(
                tiny_config(t.arch), mesh,
                jax.eval_shape(lambda b: b, base))
            sharded_base = jax.device_put(
                base, jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s), specs))
            args = (sharded_base,) + tuple(t.args[1:])
            from repro.launch.mesh import mesh_context
            with mesh_context(mesh):
                hlo = (jax.jit(t.fn, donate_argnums=t.donate_argnums)
                       .lower(*args).compile().as_text())
        results.append(collectives.audit_collectives(
            hlo, t.args[t.base_argnum], target=t.name,
            # per-layer frozen-weight gathers are the FSDP executor mode;
            # reduce-type collectives at base shape stay hard errors
            allow_kinds=("all-gather", "all-gather-start") if mesh else ()))
    return results


def run_all(*, with_isolation: bool = True, mesh=None) -> list:
    targets = all_targets()
    results = run_static(targets)
    results.append(run_buckets())
    results.extend(run_collectives(targets, mesh=mesh))
    if with_isolation:
        results.extend(run_isolation(targets))
    return results
