"""Frozen-base taint and client-isolation passes.

Two complementary proofs of Symbiosis's isolation contract:

* ``check_frozen_base`` — **syntactic** forward taint over the jaxpr: mark
  the invars bound to frozen-base leaves as tainted, close over equations
  (any tainted operand taints every result), and flag any jaxpr *output*
  that is (a) base-tainted, (b) exactly base-leaf-shaped, and (c) not the
  untouched base invar itself. A train step that returns an updated base
  tensor — the "accidentally trainable base" failure — trips all three.

* ``check_client_isolation`` / ``check_row_isolation`` — **differential**
  probes at runtime: corrupt one client's adapter slice (or one train row's
  inputs) and re-run the very same step from identical state; every other
  client's logits, cache pages, and slot rows (or every other row's updated
  params / optimizer state) must be bit-identical. The Pallas/custom_vmap
  kernels on the hot path don't admit a clean symbolic cross-client proof,
  but bit-equality under perturbation is exactly the observable contract.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.analysis.aliasing import leaf_sig
from repro.analysis.report import PassResult


def check_frozen_base(fn: Callable, args: tuple, *, base_argnum: int = 0,
                      update_argnums: tuple = (), target: str,
                      pass_name: str = "taint") -> PassResult:
    """No output of ``fn`` may be a freshly-produced base-shaped tensor.

    ``update_argnums`` name the state the step legitimately rewrites
    (adapter bank, optimizer): base signatures that coincide with an
    update-leaf signature are excluded, otherwise an adapter update whose
    leaf happens to share a shape with some base leaf (e.g. a LoRA
    [layers, d_model, rank] A against the MoE gate's
    [layers, d_model, n_experts]) would be a false positive.
    """
    res = PassResult(pass_name, target)
    closed = jax.make_jaxpr(fn)(*args)
    jaxpr = closed.jaxpr

    flat_sizes = [len(jax.tree_util.tree_leaves(a)) for a in args]
    start = sum(flat_sizes[:base_argnum])
    stop = start + flat_sizes[base_argnum]
    base_invars = jaxpr.invars[start:stop]
    base_sigs = {leaf_sig(v.aval) for v in base_invars}
    for i in update_argnums:
        base_sigs -= {leaf_sig(leaf)
                      for leaf in jax.tree_util.tree_leaves(args[i])}
    res.checked["base_leaves"] = len(base_invars)

    tainted = set(map(id, base_invars))
    for eqn in jaxpr.eqns:
        if any(id(v) in tainted for v in eqn.invars
               if not isinstance(v, jax.core.Literal)):
            tainted.update(id(v) for v in eqn.outvars)

    base_ids = set(map(id, base_invars))
    for i, v in enumerate(jaxpr.outvars):
        if isinstance(v, jax.core.Literal) or id(v) in base_ids:
            continue
        if not hasattr(v.aval, "shape"):
            continue
        if leaf_sig(v.aval) in base_sigs and id(v) in tainted:
            res.add(
                f"output {i} is a freshly-computed base-weight-shaped tensor "
                f"{v.aval.str_short()} derived from the frozen base — the "
                "step produces an updated base",
                output_index=i, aval=v.aval.str_short(),
            )
    return res


def _bit_equal(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool(np.all(a.view(np.uint8) == b.view(np.uint8)))


def check_client_isolation(fn: Callable, base, bank, caches, extra_args: tuple,
                           *, clients: np.ndarray, victim: int, pool_pages: int,
                           page_axes, slot_axes, out_row_axis: int = 0,
                           target: str, pass_name: str = "taint.isolation",
                           ) -> PassResult:
    """Corrupt ``victim``'s adapter slice; other clients must be unaffected.

    ``fn(base, bank, caches, *extra_args) -> (out, new_caches)`` with
    ``out`` carrying a leading row axis mapped to clients by ``clients``.
    ``page_axes`` / ``slot_axes`` are pytrees (matching ``caches``) giving
    the global-pool page axis / client slot axis per leaf (None = not that
    kind of leaf), as produced by ``core.symbiosis.cache_page_axes`` and
    ``cache_slot_axes``.
    """
    res = PassResult(pass_name, target)
    out0, caches0 = fn(base, bank, caches, *extra_args)

    bad_bank = jax.tree.map(
        lambda p: p.at[victim].set(jax.numpy.full_like(p[victim], 1e9))
        if hasattr(p, "ndim") and p.ndim >= 1 and p.shape[0] > victim else p,
        bank,
    )
    out1, caches1 = fn(base, bad_bank, caches, *extra_args)

    other_rows = np.nonzero(np.asarray(clients) != victim)[0]
    res.checked["other_rows"] = len(other_rows)
    for r in other_rows:
        a = np.take(np.asarray(out0), r, axis=out_row_axis)
        b = np.take(np.asarray(out1), r, axis=out_row_axis)
        if not _bit_equal(a, b):
            res.add(
                f"corrupting client {victim}'s adapter changed the output of "
                f"row {r} (client {int(np.asarray(clients)[r])}) — adapter "
                "state leaks across clients",
                row=int(r), victim=victim,
            )

    flat0 = jax.tree_util.tree_flatten_with_path(caches0)[0]
    flat1 = jax.tree.leaves(caches1)
    flat_pa = jax.tree.leaves(page_axes, is_leaf=lambda x: x is None)
    flat_sa = jax.tree.leaves(slot_axes, is_leaf=lambda x: x is None)
    n_checked = 0
    for (path, l0), l1, pa, sa in zip(flat0, flat1, flat_pa, flat_sa):
        a0, a1 = np.asarray(l0), np.asarray(l1)
        if pa is not None:
            # Global pool: client c owns pages [c*P, (c+1)*P) along axis pa.
            keep = [i for i in range(a0.shape[pa])
                    if not (victim * pool_pages <= i < (victim + 1) * pool_pages)]
            a0, a1 = np.take(a0, keep, axis=pa), np.take(a1, keep, axis=pa)
        elif sa is not None:
            keep = [i for i in range(a0.shape[sa]) if i != victim]
            a0, a1 = np.take(a0, keep, axis=sa), np.take(a1, keep, axis=sa)
        else:
            continue
        n_checked += 1
        if not _bit_equal(a0, a1):
            res.add(
                f"corrupting client {victim}'s adapter changed cache leaf "
                f"{jax.tree_util.keystr(path)} outside client {victim}'s "
                "pages/slots — cache writes leak across clients",
                leaf=jax.tree_util.keystr(path), victim=victim,
            )
    res.checked["cache_leaves_checked"] = n_checked
    return res


def check_row_isolation(step: Callable, args: tuple, *, perturb_row: int,
                        victim_slot: int, perturb_argnums: tuple,
                        row_state_outs: tuple = (0, 1),
                        target: str, pass_name: str = "taint.isolation",
                        ) -> PassResult:
    """Perturb one train row's inputs; other rows' state must be unaffected.

    ``step(*args)`` returns a tuple whose entries named by ``row_state_outs``
    are pytrees with a leading bank-slot axis (new adapter params, new opt
    state). ``perturb_argnums`` name the args whose ``[perturb_row]`` slice
    gets corrupted (batch tokens, per-row hyperparams); ``victim_slot`` is
    the bank slot that row scatters into — every OTHER slot must come out
    bit-identical.
    """
    res = PassResult(pass_name, target)
    out0 = step(*args)

    def corrupt(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] > perturb_row:
            fill = 3 if np.issubdtype(np.asarray(x).dtype, np.integer) else 1e6
            return x.at[perturb_row].set(jax.numpy.full_like(x[perturb_row], fill))
        return x

    args1 = tuple(jax.tree.map(corrupt, a) if i in perturb_argnums else a
                  for i, a in enumerate(args))
    out1 = step(*args1)

    n_checked = 0
    for oi in row_state_outs:
        flat0 = jax.tree_util.tree_flatten_with_path(out0[oi])[0]
        flat1 = jax.tree.leaves(out1[oi])
        for (path, l0), l1 in zip(flat0, flat1):
            a0, a1 = np.asarray(l0), np.asarray(l1)
            if a0.ndim < 1 or a0.shape[0] <= victim_slot:
                continue
            keep = [i for i in range(a0.shape[0]) if i != victim_slot]
            n_checked += 1
            if not _bit_equal(np.take(a0, keep, 0), np.take(a1, keep, 0)):
                res.add(
                    f"perturbing train row {perturb_row}'s inputs changed "
                    f"output {oi} leaf {jax.tree_util.keystr(path)} outside "
                    f"bank slot {victim_slot} — per-row fine-tuning state "
                    "leaks across jobs",
                    output_index=oi, leaf=jax.tree_util.keystr(path),
                )
    res.checked["row_leaves_checked"] = n_checked
    return res
