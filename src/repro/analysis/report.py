"""Violation / PassResult containers and the JSON report format.

Every pass produces one ``PassResult`` per analyzed target (a named
hot-path step on a named config). A result is *clean* when it has no
error-severity violations; warnings (e.g. a declared-unbounded trace
domain) are reported but do not fail the run.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass
class Violation:
    """One broken contract instance, attributed to a pass and a target."""

    pass_name: str
    target: str
    message: str
    severity: str = ERROR
    detail: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{self.severity}] {self.pass_name} @ {self.target}: {self.message}"


@dataclasses.dataclass
class PassResult:
    """Outcome of running one pass over one target."""

    pass_name: str
    target: str
    violations: list[Violation] = dataclasses.field(default_factory=list)
    checked: dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(v.severity == ERROR for v in self.violations)

    def add(self, message: str, severity: str = ERROR, **detail: Any) -> Violation:
        v = Violation(self.pass_name, self.target, message, severity, detail)
        self.violations.append(v)
        return v

    def to_dict(self) -> dict[str, Any]:
        return {
            "pass": self.pass_name,
            "target": self.target,
            "ok": self.ok,
            "violations": [v.to_dict() for v in self.violations],
            "checked": self.checked,
        }


def report_payload(results: list[PassResult]) -> dict[str, Any]:
    """Machine-readable summary of a full analysis run."""
    return {
        "ok": all(r.ok for r in results),
        "n_passes": len(results),
        "n_violations": sum(len(r.violations) for r in results),
        "results": [r.to_dict() for r in results],
    }


def render_report(results: list[PassResult], as_json: bool = False) -> str:
    """Human (or JSON) rendering of a full analysis run."""
    if as_json:
        return json.dumps(report_payload(results), indent=2, default=str)
    lines = []
    for r in sorted(results, key=lambda r: (r.pass_name, r.target)):
        mark = "ok " if r.ok else "FAIL"
        extras = " ".join(f"{k}={v}" for k, v in r.checked.items())
        lines.append(f"{mark} {r.pass_name:<12} {r.target:<40} {extras}")
        for v in r.violations:
            lines.append(f"     !! [{v.severity}] {v.message}")
    n_err = sum(1 for r in results for v in r.violations if v.severity == ERROR)
    n_warn = sum(1 for r in results for v in r.violations if v.severity == WARNING)
    lines.append(
        f"-- {len(results)} pass runs, {n_err} errors, {n_warn} warnings --"
    )
    return "\n".join(lines)
