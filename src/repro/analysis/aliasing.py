"""Donation/aliasing pass: donated state must survive as a true alias.

The hidden-copy class PR 3 hit: a buffer is donated to ``jax.jit`` but XLA
cannot alias it (dtype/shape mismatch with any output, or the argument is
silently pruned as unused), so every step materializes a fresh pool-sized
allocation — with **no** compile-time warning on the default
``keep_unused=False`` path. This pass parses the compiled HLO header and
proves, per donated leaf, that an ``input_output_alias`` entry consumes a
parameter of exactly that shape/dtype. It also proves the converse for the
frozen base: no base-weight parameter may be aliased (aliasing the base
would mean the step overwrites shared weights in place).

Identification is by (hlo dtype, dims) multiset matching against the
``entry_computation_layout`` parameter list — parameter numbering cannot be
trusted because XLA prunes unused (even donated) arguments from the entry
layout entirely; a donated leaf whose shape is absent from the aliased-
parameter multiset is exactly the silently-dropped-donation failure mode.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Any

import jax
import numpy as np

from repro.analysis.report import PassResult

# f32[2,16,8]{...} — reuse the dims; layout suffix optional.
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
# { {out_index}: (param_number, {}, may-alias) } entries.
_ALIAS_ENTRY_RE = re.compile(r"\{[0-9, ]*\}:\s*\((\d+),\s*\{[^}]*\}(?:,\s*[\w-]+)?\)")
_ENTRY_LAYOUT_RE = re.compile(r"entry_computation_layout=\{\((.*?)\)->", re.S)


def _balanced_block(text: str, key: str):
    """Contents of the brace block following ``key`` (entries themselves
    contain nested ``{}`` so a non-greedy regex can't delimit it)."""
    i = text.find(key)
    if i < 0:
        return None
    i = text.index("{", i + len(key))
    depth, start = 0, i + 1
    for j in range(i, len(text)):
        depth += {"{": 1, "}": -1}.get(text[j], 0)
        if depth == 0:
            return text[start:j]
    return None

_HLO_DTYPE = {
    "float32": "f32", "float64": "f64", "float16": "f16", "bfloat16": "bf16",
    "int8": "s8", "int16": "s16", "int32": "s32", "int64": "s64",
    "uint8": "u8", "uint16": "u16", "uint32": "u32", "uint64": "u64",
    "bool": "pred",
    "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2",
}


def hlo_dtype(dtype: Any) -> str:
    """numpy/jax dtype -> HLO element-type string (e.g. float32 -> f32)."""
    return _HLO_DTYPE.get(np.dtype(dtype).name, np.dtype(dtype).name)


def leaf_sig(leaf: Any) -> tuple[str, tuple[int, ...]]:
    """(hlo dtype, dims) signature of an array(-like) leaf."""
    return hlo_dtype(leaf.dtype), tuple(leaf.shape)


def parse_entry_params(hlo_text: str) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (dtype, dims) of the entry computation's *kept* parameters."""
    m = _ENTRY_LAYOUT_RE.search(hlo_text)
    if not m:
        return []
    out = []
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def parse_aliased_params(hlo_text: str) -> list[int]:
    """Parameter numbers consumed by input_output_alias entries."""
    block = _balanced_block(hlo_text, "input_output_alias=")
    if block is None:
        return []
    return [int(p) for p in _ALIAS_ENTRY_RE.findall(block)]


def compile_text(fn, args, donate_argnums=()) -> str:
    """Compiled-HLO text of ``jit(fn)`` on ``args`` (abstract compile only)."""
    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums))
    return jitted.lower(*args).compile().as_text()


def check_donation(
    hlo_text: str,
    donated_leaves,
    *,
    target: str,
    frozen_leaves=(),
    pass_name: str = "donation",
) -> PassResult:
    """Check donated leaves alias through; frozen leaves never do.

    ``donated_leaves``: (path, leaf) pairs that were donated and must each
    map onto a distinct aliased parameter of identical (dtype, dims).
    ``frozen_leaves``: (path, leaf) pairs (the base) that must account for
    zero of the aliased parameters.
    """
    res = PassResult(pass_name, target)
    params = parse_entry_params(hlo_text)
    aliased = parse_aliased_params(hlo_text)
    sig_budget: Counter = Counter()
    for p in aliased:
        if p >= len(params):
            res.add(f"alias entry references parameter {p} outside entry layout "
                    f"({len(params)} params)", param=p)
            continue
        sig_budget[params[p]] += 1
    res.checked["aliased_params"] = len(aliased)
    res.checked["donated_leaves"] = len(donated_leaves)

    for path, leaf in donated_leaves:
        sig = leaf_sig(leaf)
        if sig_budget[sig] > 0:
            sig_budget[sig] -= 1
        else:
            res.add(
                f"donated buffer {path} {sig[0]}{list(sig[1])} has no "
                "input-output alias in the compiled executable — the donation "
                "was silently dropped (unused-arg pruning or shape mismatch) "
                "and each step will materialize a fresh copy",
                path=str(path), dtype=sig[0], dims=list(sig[1]),
            )

    # Whatever alias budget remains must not be explainable only by a frozen
    # (base) leaf: an aliased parameter with a base-weight signature that no
    # donated leaf claimed means the executable overwrites the shared base.
    frozen_sigs = Counter(leaf_sig(leaf) for _, leaf in frozen_leaves)
    for sig, n in sig_budget.items():
        if n > 0 and frozen_sigs[sig] > 0:
            res.add(
                f"{n} aliased parameter(s) of frozen-base shape "
                f"{sig[0]}{list(sig[1])} not claimed by any donated buffer — "
                "the step aliases (overwrites) shared base weights",
                dtype=sig[0], dims=list(sig[1]), count=n,
            )
    return res


def donated_leaf_paths(tree) -> list[tuple[str, Any]]:
    """Flatten a pytree into (path-string, leaf) pairs."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
