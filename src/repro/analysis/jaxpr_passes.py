"""Jaxpr traversal passes: pool-copy detector and MoE-remat structure.

Generalizes the PR-5 one-off "no scan stacks a pool-shaped ys" assertion
into a default-deny rule over the whole (recursive) jaxpr: **no equation
may produce a pool-sized output** unless it belongs to the small set of
in-place / pass-through forms —

* ``scatter`` / ``scatter-add`` / ``dynamic_update_slice`` — the in-place
  write family XLA lowers to an aliased update;
* ``reshape`` — the layer-axis fold of the global pool is a bitcast;
* carry outputs of ``scan`` / ``while`` — state threaded through a loop
  (XLA aliases loop carries), while a pool-sized scan **ys** output means
  the loop stacked per-iteration pool copies (PR 5's bug class);
* call-like containers (``pjit``, ``remat2``, ``custom_*``, ``cond``) —
  not flagged themselves, but their body jaxprs are walked recursively.

Anything else at pool size — ``concatenate``, ``gather``, ``transpose``,
``broadcast_in_dim``, ``select_n``, ``convert_element_type``, ``copy``,
arithmetic — materializes a fresh pool-sized buffer on the hot path and is
reported. Protected leaves are identified by exact (dtype, dims) signature
— byte counts alone collide with unrelated tensors (a gathered [R, ...]
adapter row can share nbytes with a smaller full-bank leaf) — and the
signature set grows through bitcast ops: a ``reshape`` whose *input* is
pool-sized protects its output's shape too, so the layer-axis fold of the
global pool stays covered. Callers derive the protected set structurally
(``core.symbiosis.cache_page_axes`` / ``cache_slot_axes``), never by shape
heuristics.

The same walker hosts the MoE structural contract: every ``top_k`` routing
equation in a train step must sit under a ``remat2`` (``jax.checkpoint``)
sub-jaxpr, i.e. the route→dispatch→combine body is rematerialized rather
than saving expert-sized residuals (PR 5's bitwise-reproducibility fix).
"""
from __future__ import annotations

from typing import Any, Iterator

import jax
import numpy as np

from repro.analysis.report import PassResult

_IN_PLACE = {
    "scatter", "scatter-add", "scatter_add", "scatter-mul", "scatter-min",
    "scatter-max", "dynamic_update_slice", "dynamic-update-slice",
}
_BITCAST = {"reshape", "squeeze", "expand_dims"}
_LOOPS = {"scan", "while"}
_REMAT = {"remat2", "remat", "checkpoint"}


def _sub_jaxprs(eqn) -> Iterator[Any]:
    for v in eqn.params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for u in vs:
            if isinstance(u, jax.core.ClosedJaxpr):
                yield u.jaxpr
            elif isinstance(u, jax.core.Jaxpr):
                yield u


def _is_call_like(eqn) -> bool:
    return any(True for _ in _sub_jaxprs(eqn))


def leaf_size_sigs(leaves) -> set[tuple[str, tuple[int, ...]]]:
    """Exact (dtype name, dims) signatures of the protected leaves."""
    return {(np.dtype(leaf.dtype).name, tuple(int(d) for d in leaf.shape))
            for leaf in leaves}


def _var_sig(var) -> tuple[str, tuple[int, ...]] | None:
    aval = var.aval
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return None
    return np.dtype(aval.dtype).name, tuple(int(d) for d in aval.shape)


def check_pool_copies(jaxpr, protected_sigs, *, target: str,
                      pass_name: str = "poolcopy") -> PassResult:
    """Walk ``jaxpr`` (a Jaxpr or ClosedJaxpr); flag pool-sized materializations."""
    res = PassResult(pass_name, target)
    res.checked["protected_sigs"] = len(protected_sigs)
    sigs = set(protected_sigs)           # grows through bitcast aliases
    n_eqns = 0
    n_inplace = 0

    def protected(var) -> bool:
        sig = _var_sig(var)
        return sig is not None and sig in sigs

    def walk(jx, depth: int) -> None:
        nonlocal n_eqns, n_inplace
        for eqn in jx.eqns:
            n_eqns += 1
            prim = eqn.primitive.name
            if prim in _BITCAST and any(
                    protected(v) for v in eqn.invars
                    if not isinstance(v, jax.core.Literal)):
                # the pool under a new layout (layer fold etc.) — protect it
                for v in eqn.outvars:
                    sig = _var_sig(v)
                    if sig is not None:
                        sigs.add(sig)
            if prim == "scan":
                # a ys output stacks per-iteration values: pool-shaped slices
                # mean the loop copied the pool every step (PR 5's bug class)
                num_carry = eqn.params.get("num_carry", 0)
                for i, v in enumerate(eqn.outvars[num_carry:], num_carry):
                    sig = _var_sig(v)
                    if (sig is not None and len(sig[1]) >= 1
                            and (sig[0], sig[1][1:]) in sigs):
                        res.add(
                            "scan stacks a pool-sized ys output "
                            f"{v.aval.str_short()} (output {i}, "
                            f"{num_carry} carries) — per-iteration pool "
                            "copies on the hot path",
                            primitive=prim, outvar=v.aval.str_short(),
                        )
            hot = [i for i, v in enumerate(eqn.outvars) if protected(v)]
            if hot:
                if prim in _IN_PLACE:
                    n_inplace += 1
                elif prim in _BITCAST:
                    pass
                elif prim == "scan":
                    num_carry = eqn.params.get("num_carry", 0)
                    for i in hot:
                        if i >= num_carry:
                            v = eqn.outvars[i]
                            res.add(
                                "scan stacks a pool-sized ys output "
                                f"{v.aval.str_short()} (output {i}, "
                                f"{num_carry} carries) — per-iteration pool "
                                "copies on the hot path",
                                primitive=prim, outvar=v.aval.str_short(),
                            )
                elif prim == "while" or _is_call_like(eqn):
                    pass  # pass-through / aliased carry; body walked below
                else:
                    for i in hot:
                        v = eqn.outvars[i]
                        res.add(
                            f"op '{prim}' materializes a pool-sized "
                            f"intermediate {v.aval.str_short()} outside the "
                            "in-place scatter/dynamic-update-slice family",
                            primitive=prim, outvar=v.aval.str_short(),
                        )
            for sub in _sub_jaxprs(eqn):
                walk(sub, depth + 1)

    walk(jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr, 0)
    res.checked["eqns_walked"] = n_eqns
    res.checked["inplace_writes"] = n_inplace
    return res


def check_moe_checkpointed(jaxpr, *, target: str,
                           pass_name: str = "poolcopy.moe_remat") -> PassResult:
    """Every ``top_k`` routing eqn must live under a ``remat2`` sub-jaxpr."""
    res = PassResult(pass_name, target)
    n_topk = 0
    n_remat = 0

    def walk(jx, in_remat: bool) -> None:
        nonlocal n_topk, n_remat
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if prim in _REMAT:
                n_remat += 1
            if prim == "top_k":
                n_topk += 1
                if not in_remat:
                    res.add(
                        "MoE routing (top_k) outside any jax.checkpoint/remat2 "
                        "region — the route→dispatch→combine body saves "
                        "expert-sized residuals instead of rematerializing",
                        primitive=prim,
                    )
            for sub in _sub_jaxprs(eqn):
                walk(sub, in_remat or prim in _REMAT)

    walk(jaxpr.jaxpr if isinstance(jaxpr, jax.core.ClosedJaxpr) else jaxpr, False)
    res.checked["top_k_eqns"] = n_topk
    res.checked["remat_regions"] = n_remat
    return res
