"""Standard hot-path step targets the analysis passes run over.

A ``StepTarget`` bundles one hot-path jitted step exactly as an engine
calls it: the unjitted builder output from ``core.symbiosis``, concrete
tiny-config arguments, the donation signature the engine's own memoized
``jax.jit`` wrapper uses, and the protected-state metadata each pass needs
(donated leaves, frozen-base leaves, pool-sized signatures). The CLI and
the tier-1 mutation tests both consume these bundles, so what gets
analyzed IS the program the engines run — just at test-sized shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.analysis.aliasing import donated_leaf_paths
from repro.analysis.jaxpr_passes import leaf_size_sigs
from repro.config import (AdapterConfig, ModelConfig, ServeConfig,
                          TrainConfig, DENSE, ENCDEC, HYBRID, MOE, RWKV, VLM)
from repro.core import adapters as adapters_lib
from repro.core import symbiosis


def tiny_config(arch: str = DENSE, **kw) -> ModelConfig:
    """Analysis-sized model config (mirrors the tier-1 test shapes)."""
    base = {"name": f"analysis-{arch}", "arch": arch, "n_layers": 2,
            "d_model": 64, "n_heads": 4, "n_kv_heads": 2, "d_ff": 128,
            "vocab": 128, "dtype": "float32", "param_dtype": "float32"}
    if arch == MOE:
        base.update(n_experts=4, top_k=2, n_shared_experts=1, d_expert=32,
                    first_dense_layers=1, n_layers=3)
    if arch == RWKV:
        base.update(n_heads=4, n_kv_heads=4, head_dim=16)
    if arch == HYBRID:
        base.update(n_layers=4, attn_every=2, n_experts=4, top_k=2,
                    moe_every=2, moe_offset=1, d_state=8, d_conv=4)
    if arch == ENCDEC:
        base.update(n_enc_layers=2, n_frontend_tokens=8, rope_theta=0.0,
                    n_kv_heads=4)
    if arch == VLM:
        base.update(n_frontend_tokens=8)
    base.update(kw)
    return ModelConfig(**base)


@dataclasses.dataclass
class StepTarget:
    """One hot-path step + everything the passes need to judge it."""

    name: str
    fn: Callable                      # unjitted step
    args: tuple
    donate_argnums: tuple             # the engine's donation signature
    base_argnum: int = 0
    # pool-copy protection: leaves that must only be written in place
    protected_leaves: list = dataclasses.field(default_factory=list)
    kind: str = "serving"             # serving | train
    arch: str = DENSE
    # runtime isolation-probe hook (None = jaxpr/HLO passes only)
    isolation: Any = None

    @property
    def donated(self):
        out = []
        for i in self.donate_argnums:
            out.extend((f"arg{i}{p}", leaf)
                       for p, leaf in donated_leaf_paths(self.args[i]))
        return out

    @property
    def frozen(self):
        return donated_leaf_paths(self.args[self.base_argnum])

    @property
    def protected_sigs(self):
        return leaf_size_sigs(self.protected_leaves)

    def jaxpr(self):
        return jax.make_jaxpr(self.fn)(*self.args)


def _pool_leaves(cfg, scfg, caches):
    """The global-pool cache leaves, identified structurally (never by
    shape heuristics): leaves with a non-None page axis."""
    cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg)
    page_axes = symbiosis.cache_page_axes(cfg, scfg.max_seq, **cache_kw)
    flat_c = jax.tree.leaves(caches)
    flat_p = jax.tree.leaves(page_axes, is_leaf=lambda x: x is None)
    return [leaf for leaf, pax in zip(flat_c, flat_p) if pax is not None]


def _serving_state(cfg, acfg, scfg, *, n_clients=2, max_b=2, seed=0):
    base, bank, _ = symbiosis.init_system(
        cfg, acfg, n_clients, jax.random.PRNGKey(seed))
    cache_kw = symbiosis.serve_cache_kwargs(cfg, scfg)
    caches = symbiosis.init_client_caches(
        cfg, n_clients, max_b, scfg.max_seq, **cache_kw)
    if "page_block" in cache_kw:
        # disjoint global page assignment per (client, slot) — what the
        # engine's allocator would have pushed: client c owns [c*P, (c+1)*P)
        n_blocks = -(-scfg.max_seq // scfg.page_block)
        P = max_b * n_blocks
        tbl = np.zeros((n_clients, max_b, n_blocks), np.int32)
        for c in range(n_clients):
            for s in range(max_b):
                tbl[c, s] = c * P + s * n_blocks + np.arange(n_blocks)
        caches = dict(caches, block_tbl=jax.numpy.asarray(tbl))
    return base, bank, caches


def serving_targets(arch: str = DENSE) -> list:
    """Prefill, masked decode (dense layout), compact decode (paged),
    mixed-bank compact decode — the ServingEngine's jitted surface.

    Family-aware: attention-bearing families (dense/MoE/VLM, plus the
    hybrid and enc-dec stacks whose attention layers page) register the
    paged prefill + compact-decode pair; pure-recurrent RWKV has no paged
    layout, so it registers the dense-layout prefill instead, at TRUE
    prompt length — recurrent families never right-pad (engine
    ``_bucket``) because pads would pollute the state."""
    cfg = tiny_config(arch)
    lora = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
    C, B = 2, 2
    out = []

    scfg_p = ServeConfig(n_clients=C, max_seq=32, page_block=8)
    paged = "page_block" in symbiosis.serve_cache_kwargs(cfg, scfg_p)

    # attention families right-pad to the engine's jit bucket (8 for a
    # 6-token prompt); recurrent-bearing families prefill at true length
    S_pad = 8 if arch in (DENSE, MOE, VLM) else 6
    toks = np.zeros((B, S_pad), np.int32)
    toks[0, :6] = np.arange(1, 7)
    lengths = np.array([6, 0], np.int32)
    mask = np.array([True, False])

    nb = 4
    clients = np.array([0, 0, 1, 0], np.int32)
    slots = np.array([0, 1, 0, 0], np.int32)
    rmask = np.array([True, True, True, False])
    dtoks = np.ones((nb,), np.int32)

    if paged:
        # --- paged layout: prefill + compact decode ---------------------
        base, bank, caches = _serving_state(cfg, lora, scfg_p,
                                            n_clients=C, max_b=B)
        pool = _pool_leaves(cfg, scfg_p, caches)
        if arch != ENCDEC:
            # enc-dec admission threads encoder frames outside the engine
            # prefill path (see tests/test_compact_decode.py); its engine
            # hot-path surface is the decode pair below
            out.append(StepTarget(
                name=f"serving_prefill[{arch}-paged]",
                fn=symbiosis.make_client_prefill(cfg, lora, scfg_p),
                args=(base, bank, caches, np.int32(0), np.int32(0),
                      jax.numpy.asarray(toks), jax.numpy.asarray(lengths),
                      jax.numpy.asarray(mask)),
                donate_argnums=(2,), protected_leaves=pool, arch=arch))

        if arch in (DENSE, MOE, VLM):
            # the cross-client compacted prefill (ISSUE 10 tentpole): the
            # paged attention engine's ONE admission path — analyzed both
            # without sharing (ext=0 compiles the exact full-prefill
            # program) and with a shared-prefix row (ext_blocks=1: one row
            # reads a mapped prefix page and prefills only its suffix)
            ptoks = np.zeros((nb, S_pad), np.int32)
            ptoks[:3, :6] = np.arange(1, 7)
            plens = np.array([6, 6, 6, 0], np.int32)
            pstarts = np.zeros((nb,), np.int32)
            out.append(StepTarget(
                name=f"compact_prefill[{arch}-paged]",
                fn=symbiosis.make_compact_prefill(cfg, lora, scfg_p,
                                                  probe=True),
                args=(base, bank, caches, jax.numpy.asarray(ptoks),
                      jax.numpy.asarray(plens), jax.numpy.asarray(pstarts),
                      jax.numpy.asarray(clients), jax.numpy.asarray(slots),
                      jax.numpy.asarray(rmask)),
                donate_argnums=(2,), protected_leaves=pool, arch=arch))
            sstarts = np.array([8, 0, 0, 0], np.int32)
            out.append(StepTarget(
                name=f"compact_prefill[{arch}-shared]",
                fn=symbiosis.make_compact_prefill(cfg, lora, scfg_p,
                                                  probe=True, ext_blocks=1),
                args=(base, bank, caches, jax.numpy.asarray(ptoks),
                      jax.numpy.asarray(plens), jax.numpy.asarray(sstarts),
                      jax.numpy.asarray(clients), jax.numpy.asarray(slots),
                      jax.numpy.asarray(rmask)),
                donate_argnums=(2,), protected_leaves=pool, arch=arch))

        # probe=True: the engine compiles its per-row finite health probe
        # into the donated decode step (docs/robustness.md) — what gets
        # analyzed must be THAT program, probe mask included
        out.append(StepTarget(
            name=f"compact_decode[{arch}-paged]",
            fn=symbiosis.make_compact_decode_step(cfg, lora, scfg_p,
                                                  probe=True),
            args=(base, bank, caches, jax.numpy.asarray(dtoks),
                  jax.numpy.asarray(clients), jax.numpy.asarray(slots),
                  jax.numpy.asarray(rmask)),
            donate_argnums=(2,), protected_leaves=pool, arch=arch,
            isolation={"clients": clients, "victim": 1, "scfg": scfg_p,
                       "extra": (dtoks, clients, slots, rmask),
                       "probe": True}))

    # --- dense layout: the masked bank-wide decode path -----------------
    scfg_d = ServeConfig(n_clients=C, max_seq=32)
    base_d, bank_d, caches_d = _serving_state(cfg, lora, scfg_d,
                                              n_clients=C, max_b=B)
    active = np.zeros((C, B), bool)
    active[0, 0] = active[1, 1] = True
    out.append(StepTarget(
        name=f"masked_decode[{arch}-dense]",
        fn=symbiosis.make_masked_decode_step(cfg, lora, scfg_d),
        args=(base_d, bank_d, caches_d,
              jax.numpy.asarray(np.ones((C, B), np.int32)),
              jax.numpy.asarray(active)),
        donate_argnums=(2,), arch=arch))

    if not paged:
        # pure-recurrent family: admission runs the dense-layout masked
        # prefill (the only prefill path RWKV has)
        out.append(StepTarget(
            name=f"serving_prefill[{arch}-dense]",
            fn=symbiosis.make_client_prefill(cfg, lora, scfg_d),
            args=(base_d, bank_d, caches_d, np.int32(0), np.int32(0),
                  jax.numpy.asarray(toks), jax.numpy.asarray(lengths),
                  jax.numpy.asarray(mask)),
            donate_argnums=(2,), arch=arch))

    # --- mixed-method registry: lora + ia3 + prefix, one compact tick ---
    if arch == DENSE:
        base, bank, _ = symbiosis.init_system(
            cfg, lora, C, jax.random.PRNGKey(0))
        ia3 = AdapterConfig(method="ia3", targets=("k", "v", "down"))
        prefix = AdapterConfig(method="prefix", targets=("q", "v"),
                               n_prefix=4)
        bank_i = adapters_lib.init_client_bank(cfg, ia3, 1,
                                               jax.random.PRNGKey(3))
        bank_p = adapters_lib.init_client_bank(cfg, prefix, 1,
                                               jax.random.PRNGKey(4))
        bank_l = jax.tree.map(lambda x: x[:1], bank)
        caches_m = symbiosis.init_client_caches(
            cfg, 3, B, scfg_p.max_seq,
            **symbiosis.serve_cache_kwargs(cfg, scfg_p))
        pool_m = _pool_leaves(cfg, scfg_p, caches_m)
        mclients = np.array([0, 1, 2, 0], np.int32)
        methods = np.array([0, 1, 2, 0], np.int32)
        locs = np.array([0, 0, 0, 0], np.int32)
        out.append(StepTarget(
            name="compact_decode[mixed-lora+ia3+prefix]",
            fn=symbiosis.make_compact_decode_step(
                cfg, (lora, ia3, prefix), scfg_p, probe=True),
            args=(base, (bank_l, bank_i, bank_p), caches_m,
                  jax.numpy.asarray(dtoks), jax.numpy.asarray(mclients),
                  jax.numpy.asarray(slots), jax.numpy.asarray(methods),
                  jax.numpy.asarray(locs), jax.numpy.asarray(rmask)),
            donate_argnums=(2,), protected_leaves=pool_m, arch=arch))
    return out


def train_targets(arch: str = DENSE) -> list:
    """Compact multi-job train step + the solo baseline oracle — the
    FinetuneEngine's jitted surface and its byte-identity reference."""
    cfg = tiny_config(arch)
    lora = AdapterConfig(method="lora", rank=4, alpha=8.0, targets=("q", "v"))
    cap, R, Bt, St = 4, 2, 2, 8
    base, bank, opt = symbiosis.init_system(
        cfg, lora, cap, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jax.numpy.asarray(
            rng.integers(0, cfg.vocab, (R, Bt, St)).astype(np.int32)),
        "labels": jax.numpy.asarray(
            rng.integers(0, cfg.vocab, (R, Bt, St)).astype(np.int32)),
    }
    if arch == ENCDEC:
        # encoder frame embeddings [R, Bt, T_enc, d] — the frontend-stub
        # leaf the data pipeline threads through enc-dec train batches
        batch["frames"] = jax.numpy.asarray(
            (rng.normal(size=(R, Bt, cfg.n_frontend_tokens, cfg.d_model))
             * 0.02).astype(np.float32))
    slots = jax.numpy.asarray(np.array([0, 2], np.int32))
    rmask = jax.numpy.asarray(np.array([True, True]))
    hyper = {
        "step": jax.numpy.asarray(np.array([0, 5], np.int32)),
        "lr": jax.numpy.asarray(np.array([1e-3, 2e-3], np.float32)),
        "warmup": jax.numpy.asarray(np.array([2.0, 2.0], np.float32)),
        "total": jax.numpy.asarray(np.array([10.0, 10.0], np.float32)),
        "wd": jax.numpy.asarray(np.array([0.0, 0.01], np.float32)),
        "gnorm": jax.numpy.asarray(np.array([np.inf, 1.0], np.float32)),
    }
    # protect the full-capacity bank/opt leaves: R < cap, so any op that
    # materializes a full bank-sized tensor outside the scatter-back is a
    # hidden copy (the gathered rows are strictly smaller). Only row-matrix
    # leaves — the (cap,) int32 step counter is 16 bytes and its signature
    # collides with conv-window index vectors in the hybrid family.
    protected = [x for x in jax.tree.leaves(bank) + jax.tree.leaves(opt)
                 if x.ndim > 1]
    out = [StepTarget(
        name=f"compact_train[{arch}-lora]",
        fn=symbiosis.make_compact_train_step(cfg, lora),
        args=(base, bank, opt, batch, slots, rmask, hyper),
        donate_argnums=(1, 2), protected_leaves=protected,
        kind="train", arch=arch,
        isolation={"perturb_row": 1, "victim_slot": int(np.asarray(slots)[1]),
                   "perturb_argnums": (3, 6)})]

    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    adapter = jax.tree.map(lambda x: x[0], bank)
    opt_one = jax.tree.map(lambda x: x[0], opt)
    solo_batch = jax.tree.map(lambda x: x[0], batch)
    out.append(StepTarget(
        name=f"baseline_train[{arch}-lora]",
        fn=symbiosis.make_baseline_train_step(cfg, lora, tcfg,
                                              memory_optimized=True),
        args=(base, adapter, opt_one, solo_batch, jax.numpy.int32(0)),
        donate_argnums=(1, 2), kind="train", arch=arch))
    return out


def all_targets() -> list:
    """The CLI's standard bundle: serving across every family the engines
    serve (dense + hybrid/RWKV/enc-dec, ROADMAP carry-over), train on
    dense plus MoE (checkpoint-structure contract), the recurrent
    families, and enc-dec (frames leaf threaded like the data pipeline's
    frontend stub). VLM train stays excluded only because its img_embed
    extras have no synthetic train harness here yet."""
    return (serving_targets(DENSE)
            + serving_targets(HYBRID)
            + serving_targets(RWKV)
            + serving_targets(ENCDEC)
            + train_targets(DENSE)
            + train_targets(MOE)
            + train_targets(HYBRID)
            + train_targets(RWKV)
            + train_targets(ENCDEC))
