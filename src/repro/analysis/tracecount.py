"""Retrace/bucket-coverage pass: the hot path compiles a closed key set.

Engines declare their legal jit cache keys per step family via
``trace_domain()`` — e.g. compact decode may compile exactly the row
buckets ``{4, 8, ..., total_rows}``, prefill exactly the power-of-two
prompt buckets — and every jitted hot-path call goes through
``dispatch(owner, family, key, fn, *args)``. When a ``TraceGuard`` is
active (the analysis CLI, or the tier-1 autouse fixture in
tests/conftest.py), dispatch compares ``fn._cache_size()`` around the call:
an actual XLA compile outside the declared domain, or a second compile of
an already-compiled (engine, family, key) — a recompile on the hot path —
is a violation naming the offending shape key. With no guard active the
dispatch indirection is a plain call (no per-tick overhead).

Families may be declared ``unbounded`` (recurrent-family prefill runs at
true prompt length by design; the ``bank_prefill`` seed ablation): their
compiles are counted, never flagged. Engines that grow or register banks
at admission time bump ``_trace_epoch`` so the legitimately-new shapes
after growth don't read as hot-path recompiles.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Iterable, Optional

from repro.analysis.report import ERROR, PassResult, Violation

OK = "ok"
UNBOUNDED = "unbounded"
UNDECLARED = "undeclared"
OUT_OF_DOMAIN = "out_of_domain"


class TraceDomain:
    """A closed (or declared-open) set of legal jit keys per step family."""

    def __init__(self):
        self._fams: dict[str, tuple] = {}

    def declare(self, family: str, keys: Optional[Iterable] = None, *,
                predicate: Optional[Callable[[Any], bool]] = None,
                unbounded: bool = False) -> "TraceDomain":
        self._fams[family] = (
            frozenset(keys) if keys is not None else None, predicate, unbounded)
        return self

    def families(self) -> dict[str, Any]:
        return {f: (sorted(ks, key=repr) if ks is not None else
                    ("unbounded" if ub else "predicate"))
                for f, (ks, _, ub) in self._fams.items()}

    def check(self, family: str, key: Any) -> str:
        if family not in self._fams:
            return UNDECLARED
        keys, predicate, unbounded = self._fams[family]
        if unbounded:
            return UNBOUNDED
        if keys is not None and key in keys:
            return OK
        if predicate is not None and predicate(key):
            return OK
        return OUT_OF_DOMAIN


class TraceGuard:
    """Records hot-path compiles and turns the illegal ones into violations."""

    def __init__(self, target: str = "engine"):
        self.target = target
        self.violations: list[Violation] = []
        self.n_calls = 0
        self.n_compiles = 0
        self.n_unbounded = 0
        self._compiled: set[tuple] = set()

    def on_call(self) -> None:
        self.n_calls += 1

    def on_compile(self, owner, family: str, key: Any) -> None:
        self.n_compiles += 1
        domain = owner.trace_domain()
        status = domain.check(family, key)
        if status == UNBOUNDED:
            self.n_unbounded += 1
            return
        if status == UNDECLARED:
            self.violations.append(Violation(
                "buckets", self.target,
                f"compile in undeclared step family {family!r} (key={key!r}) "
                f"on {type(owner).__name__} — the engine's trace_domain() "
                "does not cover this jitted step",
                ERROR, {"family": family, "key": repr(key)}))
            return
        ident = (id(owner), getattr(owner, "_trace_epoch", 0), family, key)
        if status == OUT_OF_DOMAIN:
            self.violations.append(Violation(
                "buckets", self.target,
                f"hot-path compile outside the declared bucket set: family "
                f"{family!r} key={key!r} not in "
                f"{owner.trace_domain().families().get(family)}",
                ERROR, {"family": family, "key": repr(key)}))
        elif ident in self._compiled:
            self.violations.append(Violation(
                "buckets", self.target,
                f"RECOMPILE of already-compiled key {key!r} in family "
                f"{family!r} — a shape outside the declared bucket leaked "
                "into the hot path",
                ERROR, {"family": family, "key": repr(key)}))
        self._compiled.add(ident)

    def result(self, pass_name: str = "buckets") -> PassResult:
        res = PassResult(pass_name, self.target)
        res.violations = list(self.violations)
        res.checked = {"calls": self.n_calls, "compiles": self.n_compiles,
                       "unbounded_compiles": self.n_unbounded}
        return res


_ACTIVE: Optional[TraceGuard] = None


def active_guard() -> Optional[TraceGuard]:
    return _ACTIVE


@contextlib.contextmanager
def guard(target: str = "engine"):
    """Activate a TraceGuard for the dynamic extent of the block."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, TraceGuard(target)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def dispatch(owner, family: str, key: Any, fn: Callable, *args):
    """Run a jitted hot-path step, reporting any compile to the active guard.

    ``owner`` must expose ``trace_domain()``; ``fn`` must be a ``jax.jit``
    callable (its ``_cache_size()`` detects whether this call compiled).

    If the owner carries telemetry (``owner._obs``, docs/observability.md),
    compiles are additionally emitted as ``compile`` / ``recompile``
    events — the dispatch choke point is what makes TraceGuard an event
    source. With neither a guard nor telemetry attached this is a straight
    passthrough call.
    """
    g = _ACTIVE
    obs = getattr(owner, "_obs", None)
    if g is None and obs is None:
        return fn(*args)
    if g is not None:
        g.on_call()
    before = fn._cache_size()
    out = fn(*args)
    if fn._cache_size() > before:
        if g is not None:
            g.on_compile(owner, family, key)
        if obs is not None:
            obs.on_dispatch_compile(owner, family, key,
                                    getattr(owner, "_trace_epoch", 0))
    return out
