import os
if "XLA_FLAGS" not in os.environ:               # noqa: E402 — see below
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""Invariant-checker CLI: ``python -m repro.analysis --all [--json]``.

Runs every pass over the standard hot-path targets (see ``targets.py``)
and exits nonzero if any error-severity violation survives. The XLA_FLAGS
line above MUST stay the first statement: jax fixes the device count at
first initialization, and the collective audit (``--mesh``, included in
``--all``) compiles the steps under a (data=2, model=2) mesh of host
devices.

  --static     donation / poolcopy / moe-remat / frozen-base passes only
  --buckets    engine workload under the trace-count guard only
  --isolation  differential client/row isolation probes only
  --mesh       collective audit under the 2x2 host mesh only
  --all        everything (the CI gate)
  --json       machine-readable report on stdout
  --out PATH   also write the JSON report to PATH
"""
import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--all", action="store_true", help="run every pass")
    ap.add_argument("--static", action="store_true")
    ap.add_argument("--buckets", action="store_true")
    ap.add_argument("--isolation", action="store_true")
    ap.add_argument("--mesh", action="store_true")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON report here")
    args = ap.parse_args(argv)
    if not (args.all or args.static or args.buckets or args.isolation
            or args.mesh):
        args.all = True

    from repro.analysis import runner
    from repro.analysis.report import render_report, report_payload
    from repro.analysis.targets import all_targets

    results = []
    targets = all_targets()
    if args.all or args.static:
        results.extend(runner.run_static(targets))
    if args.all or args.buckets:
        results.append(runner.run_buckets())
    if args.all or args.mesh:
        from repro.launch.mesh import _make_mesh
        mesh = _make_mesh((2, 2), ("data", "model"))
        results.extend(runner.run_collectives(targets, mesh=mesh))
    if args.all or args.isolation:
        results.extend(runner.run_isolation(targets))

    payload = report_payload(results)
    print(render_report(results, as_json=args.json))
    if args.out:
        import json
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
