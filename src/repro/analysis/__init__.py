"""repro.analysis — machine-checked performance contracts of the hot path.

Symbiosis's value proposition rests on structural invariants that profiling
cannot see and unit tests only catch one instance at a time: the frozen base
is never copied, gathered, or updated; pools/caches/optimizer state are
rebound in place (true XLA input-output aliases, not silent copies); the
jitted hot path compiles a closed, declared set of shapes (no recompiles
mid-service); and client state never leaks across clients. This package
turns each of those contracts into a named static-analysis pass over the
jaxprs and compiled HLO of every hot-path step:

* ``donation``    — every donated pool/cache/opt buffer survives as a true
                    input-output alias in the compiled executable
                    (``analysis.aliasing``); the frozen base is never
                    aliased (never overwritten in place).
* ``poolcopy``    — no op materializes a pool-sized intermediate outside
                    in-place scatter/dynamic-update-slice/carry threading
                    (``analysis.jaxpr_passes``), generalizing the PR-5
                    "no scan stacks a pool-shaped ys" assertion; plus the
                    MoE-body-checkpointed structural contract.
* ``buckets``     — engines declare their closed set of legal jit cache
                    keys; a trace-count guard flags any compile outside it
                    (``analysis.tracecount``).
* ``collectives`` — compiled-HLO audit flagging collectives whose operand
                    or result is base-weight-sized — the "no accidental
                    all-gather of the base" precondition for sharding
                    (``analysis.collectives`` over ``launch.hlo_analysis``).
* ``taint``       — jaxpr-level frozen-base taint (no step output is an
                    updated base-weight tensor) and differential
                    client-isolation probes (perturbing one client's
                    adapter/job state leaves every other client's outputs
                    and state bit-identical) (``analysis.taint``).

Run locally:  ``PYTHONPATH=src python -m repro.analysis --all``
(see docs/invariants.md). Every pass ships a mutation self-test in
tests/test_analysis.py: a deliberately broken program the pass must catch,
next to the real engine step it must pass.
"""
from repro.analysis.report import PassResult, Violation

__all__ = ["PassResult", "Violation"]
